//! Gram-matrix PCA over sets of high-dimensional gradients.
//!
//! The Sec. 2 analysis asks: of the T accumulated epoch gradients
//! `g_1..g_T in R^M`, how many principal components explain 95%/99% of the
//! variance (N95/N99-PCA, paper Alg. 2)? With T << M we never form the
//! M x M covariance: the nonzero spectrum of `G G^T / ...` equals that of
//! the T x T Gram matrix `K_ij = <g_i, g_j>`, and the principal directions
//! are recovered as linear combinations `u_k = G^T w_k / sigma_k` of the
//! stored gradients (paper's `get_PCA_components`).
//!
//! Matching the paper's pseudocode (which runs SVD on the raw stacked
//! gradients), we do **not** mean-center: the singular values of G are the
//! quantities whose cumulative share defines N-PCA.

use super::jacobi::eigh;
use super::vec_ops::dot;

/// PCA state over a growing set of gradients (rows).
pub struct GramPca {
    dim: usize,
    grads: Vec<Vec<f32>>,
    /// Cached Gram matrix, grown incrementally (row-major, len = n*n).
    gram: Vec<f64>,
}

/// Number of leading components whose singular values account for
/// `fraction` of the total singular-value mass (the paper's
/// `estimate_optimal_ncomponents`: share of *aggregated singular values*).
pub fn explained_components(singular_values: &[f64], fraction: f64) -> usize {
    let total: f64 = singular_values.iter().sum();
    if total <= 0.0 {
        return 0;
    }
    let mut acc = 0.0;
    for (i, s) in singular_values.iter().enumerate() {
        acc += s;
        if acc / total >= fraction {
            return i + 1;
        }
    }
    singular_values.len()
}

impl GramPca {
    pub fn new(dim: usize) -> Self {
        Self { dim, grads: Vec::new(), gram: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.grads.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grads.is_empty()
    }

    pub fn grad(&self, i: usize) -> &[f32] {
        &self.grads[i]
    }

    /// Append a gradient, extending the Gram matrix by one row/column
    /// (O(n * M) — the incremental path that makes per-epoch N-PCA cheap).
    pub fn push(&mut self, g: Vec<f32>) {
        assert_eq!(g.len(), self.dim);
        let n = self.grads.len();
        let mut new_gram = vec![0f64; (n + 1) * (n + 1)];
        for i in 0..n {
            for j in 0..n {
                new_gram[i * (n + 1) + j] = self.gram[i * n + j];
            }
        }
        for i in 0..n {
            let d = dot(&self.grads[i], &g);
            new_gram[i * (n + 1) + n] = d;
            new_gram[n * (n + 1) + i] = d;
        }
        new_gram[n * (n + 1) + n] = dot(&g, &g);
        self.gram = new_gram;
        self.grads.push(g);
    }

    /// Singular values of the stacked gradient matrix (descending).
    pub fn singular_values(&self) -> Vec<f64> {
        let n = self.grads.len();
        if n == 0 {
            return Vec::new();
        }
        let (vals, _) = eigh(&self.gram, n);
        vals.into_iter().map(|v| v.max(0.0).sqrt()).collect()
    }

    /// `(N95, N99)` — the paper's headline quantities per epoch.
    pub fn n_pca(&self) -> (usize, usize) {
        let sv = self.singular_values();
        (
            explained_components(&sv, 0.95),
            explained_components(&sv, 0.99),
        )
    }

    /// Principal gradient directions spanning `fraction` of the variance:
    /// unit vectors in R^M, as rows. `u_k = sum_i w_k[i] g_i / sigma_k`.
    pub fn principal_directions(&self, fraction: f64) -> Vec<Vec<f32>> {
        let n = self.grads.len();
        if n == 0 {
            return Vec::new();
        }
        let (vals, vecs) = eigh(&self.gram, n);
        let sv: Vec<f64> = vals.iter().map(|v| v.max(0.0).sqrt()).collect();
        let k = explained_components(&sv, fraction);
        let mut out = Vec::with_capacity(k);
        for c in 0..k {
            if sv[c] <= 1e-12 {
                break;
            }
            let mut u = vec![0f32; self.dim];
            for (i, g) in self.grads.iter().enumerate() {
                let w = (vecs[c][i] / sv[c]) as f32;
                if w != 0.0 {
                    for (uj, gj) in u.iter_mut().zip(g) {
                        *uj += w * gj;
                    }
                }
            }
            out.push(u);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vec_ops::{cosine, norm2};
    use crate::util::rng::Rng;

    #[test]
    fn explained_components_basics() {
        assert_eq!(explained_components(&[10.0, 0.0, 0.0], 0.95), 1);
        assert_eq!(explained_components(&[5.0, 4.0, 1.0], 0.95), 3);
        assert_eq!(explained_components(&[5.0, 4.0, 1.0], 0.9), 2);
        assert_eq!(explained_components(&[], 0.95), 0);
    }

    #[test]
    fn rank_one_family_has_one_component() {
        let mut pca = GramPca::new(200);
        let mut r = Rng::new(1);
        let base: Vec<f32> = (0..200).map(|_| r.normal_f32(0.0, 1.0)).collect();
        for i in 1..=10 {
            pca.push(base.iter().map(|x| x * i as f32).collect());
        }
        let (n95, n99) = pca.n_pca();
        assert_eq!(n95, 1);
        assert_eq!(n99, 1);
    }

    #[test]
    fn orthogonal_family_is_full_rank() {
        let mut pca = GramPca::new(64);
        for i in 0..8 {
            let mut v = vec![0f32; 64];
            v[i] = 1.0;
            pca.push(v);
        }
        let sv = pca.singular_values();
        assert_eq!(sv.len(), 8);
        for s in &sv {
            assert!((s - 1.0).abs() < 1e-8);
        }
        // Equal singular values: 95% needs ceil(0.95*8)=8 components.
        assert_eq!(pca.n_pca().0, 8);
    }

    #[test]
    fn singular_values_match_direct_svd_small() {
        // 3 vectors in R^4 with known structure.
        let mut pca = GramPca::new(4);
        pca.push(vec![1.0, 0.0, 0.0, 0.0]);
        pca.push(vec![1.0, 1.0, 0.0, 0.0]);
        pca.push(vec![0.0, 0.0, 2.0, 0.0]);
        let sv = pca.singular_values();
        // Frobenius^2 = sum sigma^2 = 1 + 2 + 4 = 7
        let f2: f64 = sv.iter().map(|s| s * s).sum();
        assert!((f2 - 7.0).abs() < 1e-9);
        assert_eq!(sv.len(), 3);
    }

    #[test]
    fn principal_directions_unit_norm_and_span() {
        let mut r = Rng::new(5);
        let mut pca = GramPca::new(100);
        // Two latent directions, 12 noisy combinations.
        let a: Vec<f32> = (0..100).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..100).map(|_| r.normal_f32(0.0, 1.0)).collect();
        for _ in 0..12 {
            let (ca, cb) = (r.normal_f32(0.0, 1.0), r.normal_f32(0.0, 1.0));
            let v: Vec<f32> = a
                .iter()
                .zip(&b)
                .map(|(x, y)| ca * x + cb * y + r.normal_f32(0.0, 0.001))
                .collect();
            pca.push(v);
        }
        let dirs = pca.principal_directions(0.99);
        assert!(dirs.len() <= 4, "should be ~2 dirs, got {}", dirs.len());
        for d in &dirs {
            assert!((norm2(d).sqrt() - 1.0).abs() < 1e-3);
        }
        // Every stored gradient should be ~in the span of the PGDs.
        for i in 0..pca.len() {
            let g = pca.grad(i).to_vec();
            let mut residual = g.clone();
            for d in &dirs {
                let c = dot(&residual, d) as f32;
                for (rj, dj) in residual.iter_mut().zip(d) {
                    *rj -= c * dj;
                }
            }
            assert!(norm2(&residual) < 1e-2 * norm2(&g).max(1e-12));
            let _ = cosine(&g, &dirs[0]); // exercised for API coverage
        }
    }
}
