//! Dense linear algebra substrate.
//!
//! Everything the reproduction needs that would normally come from
//! LAPACK/sklearn: unrolled f32 vector kernels for the LBGM hot path
//! ([`vec_ops`]), a grow-only scratch-buffer arena that keeps that hot
//! path allocation-free ([`workspace`]), a cyclic-Jacobi symmetric
//! eigensolver ([`jacobi`]), Gram-matrix PCA over flat row-major gradient
//! families ([`gram_pca`]) for the Sec. 2 analysis, and a truncated SVD
//! via subspace iteration ([`svd`]) for the ATOMO baseline.

pub mod gram_pca;
pub mod jacobi;
pub mod svd;
pub mod vec_ops;
pub mod workspace;

pub use gram_pca::{explained_components, GradFamily, GramPca};
pub use jacobi::eigh;
pub use svd::truncated_svd;
pub use vec_ops::{
    axpy, cosine, dot, norm2, projection_stats, scale, scale_add, ProjectionStats,
};
pub use workspace::Workspace;
