//! Truncated SVD via subspace iteration — the ATOMO substrate.
//!
//! ATOMO (Wang et al., 2018) compresses a gradient reshaped to a matrix
//! `A in R^{m x n}` by its leading rank-r atomic (singular) decomposition.
//! We compute the top-r triple (U, S, V) with block subspace iteration on
//! `A A^T` (or `A^T A`, whichever side is smaller), orthonormalizing with
//! modified Gram-Schmidt. Deterministic seeding keeps runs reproducible.

use crate::util::rng::Rng;

/// Rank-r truncated SVD: returns (u, s, v) with `u: r x m`, `s: r`,
/// `v: r x n` (rows are the singular vectors) such that
/// `A ~= sum_k s[k] * u[k] v[k]^T`.
pub fn truncated_svd(
    a: &[f32],
    m: usize,
    n: usize,
    rank: usize,
    iters: usize,
    seed: u64,
) -> (Vec<Vec<f32>>, Vec<f32>, Vec<Vec<f32>>) {
    assert_eq!(a.len(), m * n);
    let r = rank.min(m.min(n));
    if r == 0 || m == 0 || n == 0 {
        return (Vec::new(), Vec::new(), Vec::new());
    }
    // Iterate on the smaller side for cost O(iters * r * m * n).
    let transpose = m > n; // iterate in R^min(m,n)
    let (rows, cols) = if transpose { (n, m) } else { (m, n) };
    // B is rows x cols view of A (possibly transposed), accessed via closure.
    let at = |i: usize, j: usize| -> f32 {
        if transpose {
            a[j * n + i]
        } else {
            a[i * n + j]
        }
    };

    // Initialize Q: r x rows, random then orthonormalized.
    let mut rng = Rng::new(seed ^ 0xA70_30D0_5EED_u64);
    let mut q: Vec<Vec<f64>> = (0..r)
        .map(|_| (0..rows).map(|_| rng.normal()).collect())
        .collect();
    mgs(&mut q);

    let mut tmp = vec![0f64; cols];
    for _ in 0..iters.max(1) {
        // Q <- orth( B B^T Q ) applied vector-wise.
        for k in 0..r {
            // tmp = B^T q_k  (cols)
            for j in 0..cols {
                let mut acc = 0f64;
                for i in 0..rows {
                    acc += at(i, j) as f64 * q[k][i];
                }
                tmp[j] = acc;
            }
            // q_k = B tmp (rows)
            for i in 0..rows {
                let mut acc = 0f64;
                for j in 0..cols {
                    acc += at(i, j) as f64 * tmp[j];
                }
                q[k][i] = acc;
            }
        }
        mgs(&mut q);
    }

    // Singular values / right factors: w_k = B^T q_k, sigma = ||w_k||.
    let mut u_rows: Vec<Vec<f32>> = Vec::with_capacity(r);
    let mut s_vals: Vec<f32> = Vec::with_capacity(r);
    let mut v_rows: Vec<Vec<f32>> = Vec::with_capacity(r);
    for k in 0..r {
        let mut w = vec![0f64; cols];
        for j in 0..cols {
            let mut acc = 0f64;
            for i in 0..rows {
                acc += at(i, j) as f64 * q[k][i];
            }
            w[j] = acc;
        }
        let sigma = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        let w_unit: Vec<f32> = if sigma > 0.0 {
            w.iter().map(|x| (*x / sigma) as f32).collect()
        } else {
            vec![0f32; cols]
        };
        let q_f32: Vec<f32> = q[k].iter().map(|x| *x as f32).collect();
        s_vals.push(sigma as f32);
        if transpose {
            // B = A^T: left vectors of B live in R^n (=rows), right in R^m.
            u_rows.push(w_unit); // in R^m
            v_rows.push(q_f32); // in R^n
        } else {
            u_rows.push(q_f32); // in R^m
            v_rows.push(w_unit); // in R^n
        }
    }
    // Sort by descending sigma.
    let mut order: Vec<usize> = (0..r).collect();
    order.sort_by(|&x, &y| s_vals[y].partial_cmp(&s_vals[x]).unwrap());
    let u = order.iter().map(|&i| u_rows[i].clone()).collect();
    let s = order.iter().map(|&i| s_vals[i]).collect();
    let v = order.iter().map(|&i| v_rows[i].clone()).collect();
    (u, s, v)
}

/// Reconstruct `sum_k s[k] u[k] v[k]^T` into a dense m x n row-major matrix.
pub fn reconstruct(
    u: &[Vec<f32>],
    s: &[f32],
    v: &[Vec<f32>],
    m: usize,
    n: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for k in 0..s.len() {
        let sk = s[k];
        for i in 0..m {
            let ui = u[k][i] * sk;
            if ui == 0.0 {
                continue;
            }
            let row = &mut out[i * n..(i + 1) * n];
            for (o, vj) in row.iter_mut().zip(&v[k]) {
                *o += ui * vj;
            }
        }
    }
    out
}

/// Modified Gram-Schmidt orthonormalization of row vectors (in place).
fn mgs(q: &mut [Vec<f64>]) {
    let r = q.len();
    for k in 0..r {
        for j in 0..k {
            let d: f64 = q[k].iter().zip(&q[j]).map(|(a, b)| a * b).sum();
            let qj = q[j].clone();
            for (x, y) in q[k].iter_mut().zip(&qj) {
                *x -= d * y;
            }
        }
        let nrm: f64 = q[k].iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm > 1e-300 {
            for x in q[k].iter_mut() {
                *x /= nrm;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn frob2(a: &[f32]) -> f64 {
        a.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    #[test]
    fn exact_rank_one() {
        let (m, n) = (6, 4);
        let u0 = [1.0f32, 2.0, -1.0, 0.5, 0.0, 3.0];
        let v0 = [1.0f32, -1.0, 2.0, 0.5];
        let mut a = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                a[i * n + j] = u0[i] * v0[j];
            }
        }
        let (u, s, v) = truncated_svd(&a, m, n, 1, 12, 0);
        let rec = reconstruct(&u, &s, &v, m, n);
        let err: f64 = a
            .iter()
            .zip(&rec)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        assert!(err < 1e-8 * frob2(&a), "err={err}");
    }

    #[test]
    fn rank_r_energy_capture() {
        let (m, n, r) = (20, 15, 3);
        let mut rng = Rng::new(9);
        // A = sum of 3 strong rank-1 terms + small noise.
        let mut a = vec![0f32; m * n];
        for k in 0..r {
            let scale = 10.0 / (k + 1) as f32;
            let u: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for i in 0..m {
                for j in 0..n {
                    a[i * n + j] += scale * u[i] * v[j];
                }
            }
        }
        for x in a.iter_mut() {
            *x += rng.normal_f32(0.0, 0.01);
        }
        let (u, s, v) = truncated_svd(&a, m, n, r, 20, 1);
        assert_eq!(s.len(), r);
        assert!(s[0] >= s[1] && s[1] >= s[2]);
        let rec = reconstruct(&u, &s, &v, m, n);
        let err: f64 = a
            .iter()
            .zip(&rec)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum();
        assert!(err < 1e-3 * frob2(&a), "relative err {}", err / frob2(&a));
    }

    #[test]
    fn tall_and_wide_agree() {
        // SVD of A and A^T share singular values.
        let (m, n) = (4, 9);
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut at = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let (_, s1, _) = truncated_svd(&a, m, n, 3, 30, 5);
        let (_, s2, _) = truncated_svd(&at, n, m, 3, 30, 5);
        for k in 0..3 {
            assert!(
                (s1[k] - s2[k]).abs() < 1e-3 * s1[0].max(1.0),
                "k={k}: {} vs {}",
                s1[k],
                s2[k]
            );
        }
    }

    #[test]
    fn singular_vectors_unit_norm() {
        let mut rng = Rng::new(4);
        let (m, n) = (10, 7);
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let (u, s, v) = truncated_svd(&a, m, n, 2, 25, 2);
        for k in 0..2 {
            assert!(s[k] > 0.0);
            let nu: f64 = u[k].iter().map(|x| (*x as f64).powi(2)).sum();
            let nv: f64 = v[k].iter().map(|x| (*x as f64).powi(2)).sum();
            assert!((nu - 1.0).abs() < 1e-4);
            assert!((nv - 1.0).abs() < 1e-4);
        }
    }
}
