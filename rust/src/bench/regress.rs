//! Benchmark-regression harness: named benches, JSON reports, and a
//! tolerance gate against a committed baseline.
//!
//! The `benches/regress.rs` binary drives this module to produce
//! `BENCH_hotpath.json` — per-bench ns/op, bytes moved, and allocator
//! calls (via [`CountingAlloc`]) — and compares the run against the
//! committed `benches/baseline/hotpath_baseline.json`.
//!
//! # Why the gate is ratio-based
//!
//! Absolute ns/op differs wildly across CI machines; gating on it is how
//! bench jobs become flaky. Every gated bench here is a **pair**: the
//! optimized kernel and a naive textbook reference ([`vec_ops::reference`],
//! [`reference_topk`], a recompute-everything Gram loop — deliberately
//! no-cleverness baselines, not snapshots of previous releases) timed in
//! the same process on the same data. The gated quantity is the *ratio*
//! `ns_optimized / ns_reference`, which cancels the machine out; the
//! committed baseline stores the worst acceptable ratio and the gate
//! fails when the measured ratio exceeds it by more than the tolerance
//! (default 30%, `FEDRECYCLE_BENCH_TOLERANCE` to override). Zero-alloc
//! gates are absolute: steady-state allocator calls must stay at zero.
//!
//! [`CountingAlloc`]: super::alloc::CountingAlloc
//! [`vec_ops::reference`]: crate::linalg::vec_ops::reference
//! [`reference_topk`]: crate::compress::reference_topk

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

use super::alloc::count_allocs;

/// One regression bench's measurements.
#[derive(Clone, Debug)]
pub struct RegressBench {
    /// Stable bench name (the baseline gate keys on it).
    pub name: String,
    /// Trimmed-mean wall time per operation, nanoseconds.
    pub ns_per_op: f64,
    /// Analytic bytes moved per operation (reads + writes of the kernel's
    /// working set — for bandwidth context, not gated).
    pub bytes_per_op: u64,
    /// Allocator calls per operation (0 unless the binary installed the
    /// counting allocator and the op allocates).
    pub allocs_per_op: u64,
    /// Bytes requested from the allocator per operation.
    pub alloc_bytes_per_op: u64,
    /// Trimmed-mean ns/op of the paired naive reference, if this bench is
    /// a gated pair.
    pub ns_reference: Option<f64>,
}

impl RegressBench {
    /// `reference / optimized` — how many times faster than naive
    /// (`None` for unpaired benches).
    pub fn speedup(&self) -> Option<f64> {
        self.ns_reference.map(|r| r / self.ns_per_op)
    }

    /// `optimized / reference` — the machine-independent gated quantity.
    pub fn ratio_vs_reference(&self) -> Option<f64> {
        self.ns_reference.map(|r| self.ns_per_op / r)
    }
}

/// Bench-group runner with warmup, trimmed-mean timing, and allocation
/// counting.
pub struct Regression {
    group: String,
    samples: usize,
    warmup: usize,
    benches: Vec<RegressBench>,
}

impl Regression {
    /// A runner taking `samples` timed samples after `warmup` discarded
    /// iterations per bench.
    pub fn new(group: &str, samples: usize, warmup: usize) -> Self {
        println!("== regression group: {group} (samples={samples}) ==");
        Self {
            group: group.to_string(),
            samples: samples.max(1),
            warmup,
            benches: Vec::new(),
        }
    }

    /// Sample count from `FEDRECYCLE_BENCH_SAMPLES` (default 15; CI dials
    /// down, perf runs dial up), warmup 3.
    pub fn from_env(group: &str) -> Self {
        let samples = std::env::var("FEDRECYCLE_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(15);
        Self::new(group, samples, 3)
    }

    /// Warmup + sample `f`, returning the trimmed-mean ns per call
    /// (20% shaved off each end of the sorted samples).
    fn time_ns<T>(&self, f: &mut impl FnMut() -> T) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64() * 1e9);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trim = times.len() / 5;
        let kept = &times[trim..times.len() - trim];
        kept.iter().sum::<f64>() / kept.len() as f64
    }

    /// Time an unpaired bench.
    pub fn bench<T>(&mut self, name: &str, bytes_per_op: u64, mut f: impl FnMut() -> T) {
        let ns = self.time_ns(&mut f);
        let (_, allocs, alloc_bytes) = count_allocs(&mut f);
        self.record(RegressBench {
            name: name.to_string(),
            ns_per_op: ns,
            bytes_per_op,
            allocs_per_op: allocs,
            alloc_bytes_per_op: alloc_bytes,
            ns_reference: None,
        });
    }

    /// Time a gated pair: the optimized kernel and its naive reference on
    /// the same data in the same process (the ratio is what the baseline
    /// gates on).
    pub fn bench_pair<T, U>(
        &mut self,
        name: &str,
        bytes_per_op: u64,
        mut optimized: impl FnMut() -> T,
        mut reference: impl FnMut() -> U,
    ) {
        let ns = self.time_ns(&mut optimized);
        let ns_ref = self.time_ns(&mut reference);
        let (_, allocs, alloc_bytes) = count_allocs(&mut optimized);
        self.record(RegressBench {
            name: name.to_string(),
            ns_per_op: ns,
            bytes_per_op,
            allocs_per_op: allocs,
            alloc_bytes_per_op: alloc_bytes,
            ns_reference: Some(ns_ref),
        });
    }

    fn record(&mut self, b: RegressBench) {
        let speedup = b
            .speedup()
            .map(|x| format!("  {x:>6.2}x vs naive"))
            .unwrap_or_default();
        println!(
            "{:<40} {:>12.1} ns/op  {:>3} allocs/op{}",
            b.name, b.ns_per_op, b.allocs_per_op, speedup
        );
        self.benches.push(b);
    }

    /// All measurements so far.
    pub fn reports(&self) -> &[RegressBench] {
        &self.benches
    }

    /// The report as a JSON document (the `BENCH_hotpath.json` schema).
    pub fn to_json(&self) -> Json {
        let benches = self.benches.iter().map(|b| {
            let mut fields = vec![
                ("name", s(&b.name)),
                ("ns_per_op", num(b.ns_per_op)),
                ("bytes_per_op", num(b.bytes_per_op as f64)),
                ("allocs_per_op", num(b.allocs_per_op as f64)),
                ("alloc_bytes_per_op", num(b.alloc_bytes_per_op as f64)),
            ];
            if let Some(r) = b.ns_reference {
                fields.push(("ns_reference", num(r)));
                fields.push(("speedup_vs_reference", num(b.speedup().unwrap())));
                fields.push((
                    "ratio_vs_reference",
                    num(b.ratio_vs_reference().unwrap()),
                ));
            }
            obj(fields)
        });
        obj(vec![
            ("version", num(1.0)),
            ("group", s(&self.group)),
            ("samples", num(self.samples as f64)),
            ("benches", arr(benches)),
        ])
    }

    /// Write the JSON report to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing bench report to {}", path.display()))
    }
}

/// Load a committed baseline document.
pub fn load_baseline(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench baseline {}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("bad baseline JSON: {e}"))
}

/// Gate a run against a committed baseline; returns the list of
/// violations (empty = pass).
///
/// Baseline schema: `{"tolerance": 0.30, "gates": [{"name": ...,
/// "max_ratio_vs_reference": 0.5}, {"name": ..., "max_allocs_per_op": 0}]}`.
/// Ratio gates allow `max_ratio * (1 + tolerance)`; alloc gates are
/// absolute. A gate naming a bench the run did not produce is itself a
/// violation (renames can't silently disarm the gate).
pub fn check_baseline(run: &Regression, baseline: &Json) -> Vec<String> {
    let tolerance = std::env::var("FEDRECYCLE_BENCH_TOLERANCE")
        .ok()
        .and_then(|v| v.parse().ok())
        .or_else(|| baseline.get("tolerance").and_then(Json::as_f64))
        .unwrap_or(0.30);
    let mut violations = Vec::new();
    let gates = match baseline.get("gates").and_then(Json::as_arr) {
        Some(g) => g,
        None => return vec!["baseline has no `gates` array".into()],
    };
    for gate in gates {
        let name = match gate.get("name").and_then(Json::as_str) {
            Some(n) => n,
            None => {
                violations.push("baseline gate without `name`".into());
                continue;
            }
        };
        let bench = match run.reports().iter().find(|b| b.name == name) {
            Some(b) => b,
            None => {
                violations.push(format!("gated bench `{name}` was not run"));
                continue;
            }
        };
        if let Some(max_ratio) = gate.get("max_ratio_vs_reference").and_then(Json::as_f64)
        {
            match bench.ratio_vs_reference() {
                Some(ratio) => {
                    let limit = max_ratio * (1.0 + tolerance);
                    if ratio > limit {
                        violations.push(format!(
                            "`{name}` regressed: ns_opt/ns_ref = {ratio:.3} > \
                             allowed {limit:.3} (baseline {max_ratio:.3} + {:.0}% \
                             tolerance)",
                            tolerance * 100.0
                        ));
                    }
                }
                None => violations
                    .push(format!("gated bench `{name}` has no paired reference")),
            }
        }
        if let Some(max_allocs) = gate.get("max_allocs_per_op").and_then(Json::as_f64) {
            if bench.allocs_per_op as f64 > max_allocs {
                violations.push(format!(
                    "`{name}` allocates: {} allocs/op > allowed {max_allocs}",
                    bench.allocs_per_op
                ));
            }
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_run() -> Regression {
        let mut r = Regression::new("test", 5, 1);
        r.bench_pair("paired", 8, || std::hint::black_box(1 + 1), || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        r.bench("unpaired", 8, || std::hint::black_box(2 + 2));
        r
    }

    #[test]
    fn reports_and_json_shape() {
        let r = fake_run();
        assert_eq!(r.reports().len(), 2);
        let j = r.to_json();
        assert_eq!(j.req_usize("version").unwrap(), 1);
        let benches = j.req_arr("benches").unwrap();
        assert_eq!(benches.len(), 2);
        assert_eq!(benches[0].req_str("name").unwrap(), "paired");
        assert!(benches[0].get("speedup_vs_reference").is_some());
        assert!(benches[1].get("speedup_vs_reference").is_none());
        // Round-trips through the parser.
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.req_arr("benches").unwrap().len(), 2);
    }

    #[test]
    fn gate_passes_within_tolerance_and_fails_beyond() {
        let r = fake_run();
        let ratio = r.reports()[0].ratio_vs_reference().unwrap();
        let pass = Json::parse(&format!(
            r#"{{"tolerance": 0.3, "gates": [{{"name": "paired",
                "max_ratio_vs_reference": {}}}]}}"#,
            ratio * 2.0
        ))
        .unwrap();
        assert!(check_baseline(&r, &pass).is_empty());
        let fail = Json::parse(&format!(
            r#"{{"tolerance": 0.0, "gates": [{{"name": "paired",
                "max_ratio_vs_reference": {}}}]}}"#,
            ratio / 2.0
        ))
        .unwrap();
        assert_eq!(check_baseline(&r, &fail).len(), 1);
    }

    #[test]
    fn gate_on_missing_bench_is_a_violation() {
        let r = fake_run();
        let b = Json::parse(
            r#"{"gates": [{"name": "renamed_away", "max_ratio_vs_reference": 1.0}]}"#,
        )
        .unwrap();
        let v = check_baseline(&r, &b);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("not run"));
    }

    #[test]
    fn alloc_gate_is_absolute() {
        let r = fake_run();
        // Without the counting allocator installed the measured allocs are
        // 0, so a zero-alloc gate passes...
        let b = Json::parse(r#"{"gates": [{"name": "unpaired", "max_allocs_per_op": 0}]}"#)
            .unwrap();
        assert!(check_baseline(&r, &b).is_empty());
        // ...and an unpaired bench under a ratio gate is a violation.
        let b2 = Json::parse(
            r#"{"gates": [{"name": "unpaired", "max_ratio_vs_reference": 1.0}]}"#,
        )
        .unwrap();
        assert_eq!(check_baseline(&r, &b2).len(), 1);
    }
}
