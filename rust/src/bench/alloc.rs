//! Counting global allocator for allocation-regression benches.
//!
//! The zero-allocation claim on the steady-state LBGM round loop (§Perf,
//! `ISSUE 4`) is *measured*, not asserted by inspection: the
//! `benches/regress.rs` binary installs [`CountingAlloc`] as its
//! `#[global_allocator]` and snapshots the counters around the timed
//! region. Inside the library the counters exist but read zero unless a
//! binary opted in — counting costs two relaxed atomic increments per
//! allocator call, far too cheap to perturb what it measures.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// A [`System`]-backed allocator that counts allocation calls and bytes.
///
/// Install it in a bench binary:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: fedrecycle::bench::CountingAlloc = fedrecycle::bench::CountingAlloc;
/// ```
pub struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counters are plain
// relaxed atomics with no allocation of their own.
//
// The one unsafe block this repo permits: implementing `GlobalAlloc`
// requires it, and the impl adds nothing beyond counter bumps around
// `System` calls. Any other `unsafe` anywhere in the tree is a lint
// violation — justify a new one here or don't write it.
// lint: allow(unsafe_code, "GlobalAlloc is an unsafe trait; this impl only wraps System with relaxed counters")
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Snapshot of the global allocation counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Allocator calls (`alloc` + `realloc` + `alloc_zeroed`) so far.
    pub calls: u64,
    /// Bytes requested across those calls.
    pub bytes: u64,
}

/// Read the current counters (zero unless a binary installed
/// [`CountingAlloc`] as its global allocator).
pub fn alloc_snapshot() -> AllocSnapshot {
    AllocSnapshot {
        calls: ALLOC_CALLS.load(Ordering::Relaxed),
        bytes: ALLOC_BYTES.load(Ordering::Relaxed),
    }
}

/// Run `f` and return its result together with the allocator calls and
/// bytes it performed (as measured by [`CountingAlloc`]; `(_, 0, 0)` when
/// the counting allocator is not installed).
pub fn count_allocs<T>(f: impl FnOnce() -> T) -> (T, u64, u64) {
    let before = alloc_snapshot();
    let out = f();
    let after = alloc_snapshot();
    (out, after.calls - before.calls, after.bytes - before.bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic() {
        // The library test binary does not install CountingAlloc, so the
        // deltas are zero — what this pins is that the API is callable and
        // never goes backwards.
        let a = alloc_snapshot();
        let (v, calls, bytes) = count_allocs(|| vec![1u8; 4096]);
        assert_eq!(v.len(), 4096);
        let b = alloc_snapshot();
        assert!(b.calls >= a.calls);
        assert!(b.bytes >= a.bytes);
        assert_eq!(calls, b.calls - a.calls);
        assert_eq!(bytes, b.bytes - a.bytes);
    }
}
