//! Warmup + sampled timing with robust statistics.
//!
//! Usage from a `harness = false` bench target:
//! ```no_run
//! use fedrecycle::bench::Bencher;
//! let mut b = Bencher::from_env("hotpath");
//! b.bench("dot_1M", || { /* work */ });
//! b.finish();
//! ```

use std::time::Instant;

/// Worker-thread count for round-engine benches, from
/// `FEDRECYCLE_BENCH_THREADS` (unset or `0` = one thread per available
/// core — i.e. `Parallelism::Threads(0)` semantics).
pub fn threads_from_env() -> usize {
    std::env::var("FEDRECYCLE_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// One benchmark's statistics (seconds).
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// `group/name` of the bench.
    pub name: String,
    /// Timed samples taken (after warmup).
    pub samples: usize,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Median seconds per iteration.
    pub p50: f64,
    /// 95th-percentile seconds per iteration.
    pub p95: f64,
    /// Fastest sample, seconds.
    pub min: f64,
    /// Optional throughput annotation (unit/sec), set via `throughput`.
    pub per_sec: Option<f64>,
}

impl BenchReport {
    /// One formatted table row (what the bench binaries print).
    pub fn line(&self) -> String {
        let tp = self
            .per_sec
            .map(|t| format!("  {:>10.3} Melem/s", t / 1e6))
            .unwrap_or_default();
        format!(
            "{:<44} {:>10} {:>10} {:>10}  (n={}){}",
            self.name,
            fmt_time(self.mean),
            fmt_time(self.p50),
            fmt_time(self.p95),
            self.samples,
            tp
        )
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Bench group runner.
pub struct Bencher {
    group: String,
    samples: usize,
    warmup: usize,
    reports: Vec<BenchReport>,
    /// Elements processed per iteration for the next `bench` call.
    pending_elems: Option<u64>,
}

impl Bencher {
    /// A runner printing its table header immediately.
    pub fn new(group: &str, samples: usize, warmup: usize) -> Self {
        println!("== bench group: {group} ==");
        println!(
            "{:<44} {:>10} {:>10} {:>10}",
            "name", "mean", "p50", "p95"
        );
        Self {
            group: group.to_string(),
            samples,
            warmup,
            reports: Vec::new(),
            pending_elems: None,
        }
    }

    /// Sample counts from `FEDRECYCLE_BENCH_SAMPLES` (default 15) — CI can
    /// dial down, perf runs dial up.
    pub fn from_env(group: &str) -> Self {
        let samples = std::env::var("FEDRECYCLE_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(15);
        Self::new(group, samples, 3)
    }

    /// Named report lookup (for post-bench summaries, e.g. speedup ratios).
    pub fn mean_of(&self, name_fragment: &str) -> Option<f64> {
        self.reports
            .iter()
            .find(|r| r.name.contains(name_fragment))
            .map(|r| r.mean)
    }

    /// Annotate the next bench with a per-iteration element count.
    pub fn throughput(&mut self, elems: u64) -> &mut Self {
        self.pending_elems = Some(elems);
        self
    }

    /// Time `f` over warmup + samples iterations.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let p50 = times[times.len() / 2];
        let p95 = times[(times.len() * 95 / 100).min(times.len() - 1)];
        let per_sec = self.pending_elems.take().map(|e| e as f64 / mean);
        let report = BenchReport {
            name: format!("{}/{}", self.group, name),
            samples: self.samples,
            mean,
            p50,
            p95,
            min: times[0],
            per_sec,
        };
        println!("{}", report.line());
        self.reports.push(report);
    }

    /// Reports collected so far, in bench order.
    pub fn reports(&self) -> &[BenchReport] {
        &self.reports
    }

    /// Close the group and hand back all reports.
    pub fn finish(self) -> Vec<BenchReport> {
        println!();
        self.reports
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_collected_in_order() {
        let mut b = Bencher::new("test", 5, 1);
        b.bench("noop", || 1 + 1);
        b.throughput(1000).bench("tp", || std::hint::black_box(0));
        assert!(b.mean_of("noop").is_some());
        assert!(b.mean_of("nonexistent").is_none());
        let r = b.finish();
        assert_eq!(r.len(), 2);
        assert!(r[0].name.contains("noop"));
        assert!(r[1].per_sec.is_some());
        assert!(r[0].mean >= 0.0 && r[0].p95 >= r[0].min);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).contains('s'));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2e-6).contains("us"));
        assert!(fmt_time(2e-9).contains("ns"));
    }
}
