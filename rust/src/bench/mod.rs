//! Criterion-style micro/throughput bench harness (the build host lacks
//! `criterion`; `benches/*.rs` declare `harness = false` and drive this).
//!
//! Three pieces:
//!
//! * [`harness`] — interactive throughput benches (the `fig*`/`hotpath`
//!   binaries): warmup + robust percentiles, human-readable table.
//! * [`alloc`] — a counting [`CountingAlloc`] global allocator so bench
//!   binaries can *measure* allocation claims instead of asserting them.
//! * [`regress`] — the benchmark-regression harness behind
//!   `benches/regress.rs`: paired optimized-vs-naive timings, a
//!   `BENCH_hotpath.json` report, and a machine-independent ratio gate
//!   against the committed `benches/baseline/hotpath_baseline.json`.

pub mod alloc;
pub mod harness;
pub mod regress;

pub use alloc::{alloc_snapshot, count_allocs, AllocSnapshot, CountingAlloc};
pub use harness::{threads_from_env, BenchReport, Bencher};
pub use regress::{check_baseline, load_baseline, RegressBench, Regression};
