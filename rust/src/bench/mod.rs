//! Criterion-style micro/throughput bench harness (the build host lacks
//! `criterion`; `benches/*.rs` declare `harness = false` and drive this).

pub mod harness;

pub use harness::{threads_from_env, BenchReport, Bencher};
