//! `fedrecycle` — LBGM federated-learning launcher.
//!
//! Subcommands:
//!   info                          list artifact variants
//!   train [--config f.json] [..]  run one FL experiment arm
//!   analyze --variant V --dataset D   centralized gradient-space analysis
//!   figure <id|all> [--scale smoke|default|full] [--out results]
//!       ids: fig1 fig2 fig3 fig5 fig6 fig7 fig8 sampling theory
//!   serve  --listen ADDR [..]     networked aggregation server (TCP);
//!       with --shards N (N>=2) it becomes the sharded-topology root and
//!       accepts N aggregator trunks instead of worker sessions
//!   aggregator --connect ROOT --shard S --agg-listen ADDR   one sharded
//!       mid-tier process: owns the contiguous worker range of shard S,
//!       pre-reduces its uplinks, forwards one combined ShardUpdate
//!   worker --connect ADDR --id K  one networked worker process (under
//!       --shards, point --connect at the worker's shard aggregator)
//!   lint [--root DIR] [--report FILE]   run the fedlint static-analysis
//!       pass over the source tree (exits nonzero on any violation; see
//!       the `lint` module docs for the rules and annotation grammar)
//!   trace <run.jsonl>             summarize a trace written by --trace
//!
//! Common flags for `train`: --variant --dataset --workers --rounds --tau
//!   --eta --delta --noniid true|false --codec identity|topk|topk_ef|atomo|
//!   signsgd --codec-fraction --codec-rank --sample-fraction --seed
//!   --policy fixed|adaptive --delta2 X  (threshold policy; adaptive is
//!   rejected with --transport tcp at load time — the wire protocol cannot
//!   carry its server-side state)
//!   --parallelism seq|auto|<threads>  (round-engine concurrency; results
//!   are bit-identical across settings)
//!   --transport memory|threads|tcp  (deployment; results are bit-identical
//!   across settings — threads/tcp run the analytic mock federation in one
//!   process, since PJRT executables cannot cross threads)
//!   --faults plan.json  (deterministic chaos: a seeded FaultPlan of
//!   per-worker per-round drop/delay/disconnect/corrupt events; rounds
//!   commit with whichever workers arrive — see the `sim` module docs)
//!   --trace run.jsonl  (record the deterministic round-event stream and
//!   write it as JSONL after the run; `fedrecycle trace run.jsonl`
//!   summarizes it) and --log-level off|error|warn|info|debug (obs-layer
//!   diagnostics; default off) apply to train/serve/worker
//!   --wire-codec raw|q8|f16  (protocol-v3 wire value codec for the tcp
//!   transport and serve/worker; raw is the default and the bit-parity
//!   surface, q8/f16 trade bounded quantization error for measured wire
//!   bytes — the JSON summary's *_raw_bytes columns report the saving)
//!
//! `serve`/`worker` run the mock federation over real sockets; the two
//! sides must agree on --workers --dim --spread --sigma --seed, and every
//! worker must use the same --codec (the handshake checks id/dim/protocol;
//! federation shape and codec are the operator's contract, like the seed).
//! The server is elastic: its accept thread keeps listening for the whole
//! run, so a worker that crashes or loses its network can come back — the
//! `worker` subcommand reconnects with capped backoff (--retries,
//! --backoff-ms), bounds its serve-phase reads (--serve-timeout SECS, so a
//! server killed without closing its sockets cannot wedge the worker), and
//! re-handshakes with `Rejoin` — or the token-authenticated protocol-v3
//! `Rejoin3` on q8/f16 sessions — resuming with the next round's
//! broadcast.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use fedrecycle::analysis::gradient_space::centralized_analysis;
use fedrecycle::config::{CodecKind, ExperimentConfig, PolicyKind};
use fedrecycle::coordinator::transport::run_threaded_fl;
use fedrecycle::coordinator::{LocalTrainer, MockTrainer, Parallelism, Transport};
use fedrecycle::figures::{self, common::Scale};
use fedrecycle::metrics::{write_csv, RunSeries};
use fedrecycle::net::{
    connect_worker_with_retry, run_server_rounds_elastic, run_tcp_fl, Acceptor,
    ElasticOpts, ReconnectCfg,
};
use fedrecycle::obs;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::sim::FaultPlan;
use fedrecycle::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_env(args: &Args) -> Result<(Runtime, Manifest)> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    Ok((rt, manifest))
}

fn cfg_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(Path::new(path))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(v) = args.get("variant") {
        cfg.variant = v.into();
    }
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.into();
    }
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.rounds = args.usize_or("rounds", cfg.rounds);
    cfg.tau = args.usize_or("tau", cfg.tau);
    cfg.eta = args.f64_or("eta", cfg.eta);
    cfg.delta = args.f64_or("delta", cfg.delta);
    if let Some(v) = args.get("noniid") {
        cfg.noniid = v == "true" || v == "1";
    }
    cfg.labels_per_worker = args.usize_or("labels-per-worker", cfg.labels_per_worker);
    cfg.sample_fraction = args.f64_or("sample-fraction", cfg.sample_fraction);
    cfg.train_n = args.usize_or("train-n", cfg.train_n);
    cfg.test_n = args.usize_or("test-n", cfg.test_n);
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every);
    cfg.seed = args.u64_or("seed", cfg.seed);
    if let Some(name) = args.get("codec") {
        cfg.codec = CodecKind::parse(
            name,
            args.f64_or("codec-fraction", 0.1),
            args.usize_or("codec-rank", 2),
        )?;
    }
    if let Some(name) = args.get("policy") {
        cfg.policy = PolicyKind::parse(name, args.f64_or("delta2", 0.01))?;
    }
    if let Some(v) = args.get("parallelism") {
        cfg.parallelism = Parallelism::parse(v)?;
    }
    if let Some(v) = args.get("transport") {
        cfg.transport = Transport::parse(v)?;
    }
    if let Some(p) = args.get("faults") {
        cfg.faults = Some(FaultPlan::from_file(Path::new(p))?);
    }
    if let Some(v) = args.get("wire-codec") {
        cfg.wire_codec = fedrecycle::compress::WireCodec::parse(v)?;
    }
    cfg.shards = args.usize_or("shards", cfg.shards);
    Ok(cfg)
}

/// Honor the shared observability flags (`--log-level`, `--trace PATH`):
/// installs the global log level, and when tracing is requested returns
/// the JSONL destination plus a fresh shared recorder to thread into the
/// round engine via `FlConfig::trace`.
fn obs_from_args(args: &Args) -> Result<(Option<PathBuf>, Option<obs::TraceHandle>)> {
    if let Some(text) = args.get("log-level") {
        let level = obs::log::Level::parse(text).ok_or_else(|| {
            anyhow::anyhow!("--log-level {text}: expected off|error|warn|info|debug")
        })?;
        obs::log::set_level(level);
    }
    Ok(match args.get("trace") {
        Some(p) => (
            Some(PathBuf::from(p)),
            Some(obs::shared(obs::recorder::DEFAULT_CAPACITY)),
        ),
        None => (None, None),
    })
}

/// Flush a `--trace` recorder to its JSONL destination (no-op when
/// tracing is off).
fn flush_trace(path: &Option<PathBuf>, trace: &Option<obs::TraceHandle>) -> Result<()> {
    if let (Some(path), Some(handle)) = (path, trace) {
        let rec = handle
            .lock()
            .map_err(|_| anyhow::anyhow!("trace recorder lock poisoned"))?;
        obs::sink::write_jsonl(path, &rec)?;
        println!("trace: {} event(s) -> {}", rec.len(), path.display());
    }
    Ok(())
}

/// Shape of the analytic mock federation used by the deployment paths
/// (`train --transport threads|tcp`, `serve`, `worker`). Server and worker
/// processes must agree on these (and on --workers/--seed) for the run to
/// be well-defined.
struct MockSpec {
    dim: usize,
    spread: f32,
    sigma: f32,
}

fn mock_spec(args: &Args) -> MockSpec {
    MockSpec {
        dim: args.usize_or("dim", 64),
        spread: args.f64_or("spread", 0.3) as f32,
        sigma: args.f64_or("sigma", 0.02) as f32,
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("info") => cmd_info(args),
        Some("train") => cmd_train(args),
        Some("analyze") => cmd_analyze(args),
        Some("figure") => cmd_figure(args),
        Some("serve") => cmd_serve(args),
        Some("aggregator") => cmd_aggregator(args),
        Some("worker") => cmd_worker(args),
        Some("lint") => cmd_lint(args),
        Some("trace") => cmd_trace(args),
        _ => {
            println!("usage: fedrecycle <info|train|analyze|figure|serve|aggregator|worker|lint|trace> [flags]");
            println!("       fedrecycle figure all --scale default --out results");
            println!("       fedrecycle serve --listen 127.0.0.1:7878 --workers 4 --dim 64");
            println!("       fedrecycle worker --connect 127.0.0.1:7878 --id 0 --workers 4 --dim 64");
            println!("       fedrecycle serve --listen 127.0.0.1:7878 --workers 4 --shards 2 [..]  (sharded root)");
            println!("       fedrecycle aggregator --connect 127.0.0.1:7878 --shard 0 --agg-listen 127.0.0.1:7900 [..]");
            println!("       fedrecycle trace run.jsonl   (written by train/serve --trace)");
            Ok(())
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env(args)?;
    println!("platform: {}", rt.platform());
    println!(
        "{:<18} {:<5} {:>10} {:>7} {:<22}",
        "variant", "task", "params", "batch", "x_shape"
    );
    for v in &manifest.variants {
        println!(
            "{:<18} {:<5} {:>10} {:>7} {:<22?}",
            v.name, v.task, v.param_count, v.batch, v.x_shape
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    if cfg.transport != Transport::Memory {
        return cmd_train_deployment(args, cfg);
    }
    let (trace_path, trace) = obs_from_args(args)?;
    let (rt, manifest) = load_env(args)?;
    println!(
        "train: variant={} dataset={} K={} T={} tau={} eta={} delta={} codec={:?} par={:?}",
        cfg.variant, cfg.dataset, cfg.workers, cfg.rounds, cfg.tau, cfg.eta,
        cfg.delta, cfg.codec, cfg.parallelism
    );
    let outc = figures::common::run_arm_traced(
        &rt,
        &manifest,
        &cfg,
        &cfg.name.clone(),
        trace.clone(),
    )?;
    flush_trace(&trace_path, &trace)?;
    println!(
        "done: final metric {:.4} | floats {:>12} | bits {:>14} | scalar msgs {:.1}%",
        outc.series.final_metric(),
        outc.ledger.total_floats,
        outc.ledger.total_bits,
        100.0 * outc.series.scalar_fraction()
    );
    println!("phase timings: {}", outc.timers.report());
    if let Some(out) = args.get("out") {
        write_csv(&Path::new(out).join(format!("{}.csv", cfg.name)), &[outc.series])?;
    }
    Ok(())
}

/// `train --transport threads|tcp`: run the experiment arm as a deployment.
/// Single-process deployments need `Send` trainers and PJRT executables are
/// not `Send`, so these paths run the analytic mock federation (same
/// protocol, same ledgers); real-model networked runs use one `serve` and
/// K `worker` *processes* instead.
fn cmd_train_deployment(args: &Args, cfg: ExperimentConfig) -> Result<()> {
    // Guard the *resolved* config (flags or --config JSON): a non-default
    // variant/dataset cannot be honored on an in-process deployment.
    let defaults = ExperimentConfig::default();
    anyhow::ensure!(
        cfg.variant == defaults.variant && cfg.dataset == defaults.dataset,
        "--transport {:?} runs the analytic mock federation in-process (PJRT \
         executables are not Send), so variant/dataset `{}`/`{}` cannot be \
         honored here; use the memory transport, or a `serve` + `worker` \
         process deployment for real models",
        cfg.transport,
        cfg.variant,
        cfg.dataset
    );
    fedrecycle::config::validate(&cfg)?;
    let (trace_path, trace) = obs_from_args(args)?;
    let spec = mock_spec(args);
    let k = cfg.workers;
    let mut fl = cfg.fl_config();
    fl.trace = trace.clone();
    let mut eval = MockTrainer::new(spec.dim, k, spec.spread, 0.0, cfg.seed);
    let weights = eval.weights();
    let codec = cfg.codec;
    let make =
        |_id: usize| MockTrainer::new(spec.dim, k, spec.spread, spec.sigma, cfg.seed);
    println!(
        "train[{:?}]: mock federation K={k} dim={} T={} tau={} eta={} delta={}",
        cfg.transport, spec.dim, cfg.rounds, cfg.tau, cfg.eta, cfg.delta
    );
    let (series, ledger, _theta) = match cfg.transport {
        Transport::Threads => run_threaded_fl(
            make,
            &mut eval,
            vec![0.0; spec.dim],
            weights,
            &fl,
            &move || codec.build(),
            &cfg.name,
        )?,
        Transport::Tcp => run_tcp_fl(
            make,
            &mut eval,
            vec![0.0; spec.dim],
            weights,
            &fl,
            &move || codec.build(),
            &cfg.name,
        )?,
        Transport::Memory => unreachable!("dispatched above"),
    };
    flush_trace(&trace_path, &trace)?;
    print_deployment_summary(&series, &ledger);
    if let Some(out) = args.get("out") {
        write_csv(&Path::new(out).join(format!("{}.csv", cfg.name)), &[series])?;
    }
    Ok(())
}

fn print_deployment_summary(
    series: &RunSeries,
    ledger: &fedrecycle::coordinator::CommLedger,
) {
    println!(
        "done: final metric {:.4} | up {} floats / {} bits | down {} floats / {} bits",
        series.final_metric(),
        ledger.total_floats,
        ledger.total_bits,
        ledger.total_down_floats(),
        ledger.total_down_bits(),
    );
    println!(
        "wire: {} bytes up, {} bytes down (measured; 0 = in-memory) | scalar msgs {:.1}%",
        ledger.wire_up_bytes,
        ledger.wire_down_bytes,
        100.0 * series.scalar_fraction()
    );
    if ledger.total_faults > 0 {
        println!(
            "chaos: {} round update(s) lost to faults; worst round had {} participant(s)",
            ledger.total_faults,
            series.min_participants()
        );
    }
}

/// `serve`: the networked aggregation server. Binds `--listen`, accepts
/// `--workers` connections (handshaking in parallel), and drives the full
/// run with the accept thread kept alive throughout — a worker that drops
/// out can rejoin mid-run and resumes with the next round's broadcast.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    fedrecycle::config::validate(&cfg)?;
    let (trace_path, trace) = obs_from_args(args)?;
    let spec = mock_spec(args);
    let k = cfg.workers;
    let mut fl = cfg.fl_config();
    fl.trace = trace.clone();
    let listen = args.get_or("listen", "127.0.0.1:7878");
    let listener = TcpListener::bind(&listen)?;
    println!(
        "serve: listening on {} for K={k} workers (dim={}, T={}, delta={})",
        listener.local_addr()?,
        spec.dim,
        cfg.rounds,
        cfg.delta
    );
    let mut eval = MockTrainer::new(spec.dim, k, spec.spread, 0.0, cfg.seed);
    let weights = eval.weights();
    let handshake = Duration::from_secs(args.u64_or("handshake-timeout", 120));
    let deadline = Duration::from_secs(args.u64_or("round-deadline", 600));
    if fl.shards > 1 {
        // Sharded-topology root: the listener seats aggregator trunks
        // (`HelloShard`), not worker sessions; each round is driven over
        // combined `ShardUpdate`s — see `net::aggregator`.
        println!(
            "serve: sharded mode — waiting for {} aggregator trunk(s)",
            fl.shards
        );
        let mut trunks =
            fedrecycle::net::accept_aggregators(&listener, k, spec.dim, &fl, handshake)?;
        println!("all {} aggregators connected; training", fl.shards);
        let (series, ledger, _theta) = fedrecycle::net::run_sharded_root_rounds(
            &mut trunks,
            &mut eval,
            vec![0.0; spec.dim],
            weights,
            &fl,
            deadline,
            &cfg.name,
        )?;
        flush_trace(&trace_path, &trace)?;
        print_deployment_summary(&series, &ledger);
        if let Some(out) = args.get("out") {
            write_csv(&Path::new(out).join(format!("{}.csv", cfg.name)), &[series])?;
        }
        return Ok(());
    }
    let acceptor = Acceptor::spawn(listener, k, spec.dim, &fl, handshake)?;
    let (mut links, codecs) = acceptor.wait_for_fleet(k)?;
    let plan = fl.faults.as_ref().map(|p| std::sync::Arc::new(p.clone()));
    if let Some(p) = &plan {
        println!(
            "chaos: injecting {} fault event(s) from the plan (seed {})",
            p.events.len(),
            p.seed
        );
        links = fedrecycle::sim::chaos::wrap_links_traced(links, p, fl.trace.clone());
    }
    println!("all {k} workers connected; training (rejoins stay open)");
    let elastic = ElasticOpts {
        acceptor: &acceptor,
        plan,
        rejoin_wait: fedrecycle::net::server::DEFAULT_REJOIN_WAIT,
    };
    let (series, ledger, _theta) = run_server_rounds_elastic(
        &mut links,
        codecs,
        &mut eval,
        vec![0.0; spec.dim],
        weights,
        &fl,
        deadline,
        &cfg.name,
        Some(&elastic),
    )?;
    flush_trace(&trace_path, &trace)?;
    print_deployment_summary(&series, &ledger);
    if let Some(out) = args.get("out") {
        write_csv(&Path::new(out).join(format!("{}.csv", cfg.name)), &[series])?;
    }
    Ok(())
}

/// `aggregator`: one sharded-topology mid-tier process. Connects its
/// trunk to the root (`--connect`) as `--shard S`, then binds
/// `--agg-listen` and accepts shard S's contiguous worker range with the
/// flat worker handshake (workers point their `--connect` here). Each
/// round it re-broadcasts the root's `Round` to its shard, collects the
/// shard's uplinks under `--round-deadline`, pre-reduces them in
/// participant order, and forwards one combined `ShardUpdate` up the
/// trunk. Both sides must agree on --workers --shards --dim --spread
/// --sigma --seed (the trunk handshake checks shard/range/dim and a
/// seed-derived shard token).
fn cmd_aggregator(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    fedrecycle::config::validate(&cfg)?;
    anyhow::ensure!(
        cfg.shards > 1,
        "aggregator needs --shards >= 2 (the flat topology has no mid-tier)"
    );
    let (trace_path, _trace) = obs_from_args(args)?;
    if trace_path.is_some() {
        println!(
            "aggregator: --trace records the round-event stream root-side; \
             pass it to `serve` (only --log-level applies here)"
        );
    }
    let spec = mock_spec(args);
    let k = cfg.workers;
    let fl = cfg.fl_config();
    let shard = args.usize_or("shard", 0);
    anyhow::ensure!(
        shard < fl.shards,
        "--shard {shard} out of range (shards={})",
        fl.shards
    );
    let (lo, hi) = fedrecycle::coordinator::server::shard_bounds(shard, k, fl.shards);
    let root_addr = args.get_or("connect", "127.0.0.1:7878");
    let listen = args.get_or("agg-listen", "127.0.0.1:7900");
    let handshake = Duration::from_secs(args.u64_or("handshake-timeout", 120));
    let deadline = Duration::from_secs(args.u64_or("round-deadline", 600));
    let listener = TcpListener::bind(&listen)?;
    println!(
        "aggregator {shard}: workers [{lo}, {hi}) on {}, trunk -> {root_addr}",
        listener.local_addr()?
    );
    let stream = std::net::TcpStream::connect(root_addr.as_str())
        .with_context(|| format!("connecting trunk to root {root_addr}"))?;
    let mut root: Box<dyn fedrecycle::net::Link> =
        Box::new(fedrecycle::net::TcpLink::new(stream)?);
    fedrecycle::net::handshake_root(
        root.as_mut(),
        shard as u32,
        lo,
        hi,
        spec.dim,
        fl.seed,
    )?;
    let acceptor = Acceptor::spawn(listener, k, spec.dim, &fl, handshake)?;
    let (mut links, _codecs) = acceptor.wait_for_range(lo, hi)?;
    drop(acceptor);
    if let Some(plan) = &fl.faults {
        let p = std::sync::Arc::new(plan.clone());
        println!(
            "chaos: injecting {} fault event(s) from the plan (seed {})",
            p.events.len(),
            p.seed
        );
        links = links
            .into_iter()
            .enumerate()
            .map(|(i, l)| {
                Box::new(fedrecycle::sim::ChaosLink::wrap(l, lo + i, p.clone()))
                    as Box<dyn fedrecycle::net::Link>
            })
            .collect();
    }
    println!(
        "aggregator {shard}: all {} shard worker(s) connected; serving",
        hi - lo
    );
    let weights = MockTrainer::new(spec.dim, k, spec.spread, 0.0, cfg.seed).weights();
    fedrecycle::net::run_aggregator_rounds(
        root.as_mut(),
        &mut links,
        shard as u32,
        lo,
        spec.dim,
        &weights,
        &fl,
        deadline,
    )?;
    println!("aggregator {shard}: run complete, shut down cleanly");
    Ok(())
}

/// `worker`: one networked worker process. Connects to `--connect`, serves
/// rounds until the server shuts the session down. A lost connection is
/// retried with capped exponential backoff (`--retries`, `--backoff-ms`),
/// rejoining the run mid-flight with LBGM state intact.
fn cmd_worker(args: &Args) -> Result<()> {
    let cfg = cfg_from_args(args)?;
    let (trace_path, _trace) = obs_from_args(args)?;
    if trace_path.is_some() {
        println!(
            "worker: --trace records the round-event stream server-side; \
             pass it to `serve` (only --log-level applies here)"
        );
    }
    let spec = mock_spec(args);
    let id = args.usize_or("id", 0);
    let addr = args.get_or("connect", "127.0.0.1:7878");
    anyhow::ensure!(id < cfg.workers, "--id {id} out of range (K={})", cfg.workers);
    let retry = ReconnectCfg {
        max_attempts: args.usize_or("retries", ReconnectCfg::default().max_attempts),
        initial_backoff: Duration::from_millis(args.u64_or("backoff-ms", 25)),
        // Default pairs with `serve`'s --round-deadline default (600s)
        // plus slack; 0 disables the bound (the pre-v3 behavior).
        serve_timeout: Duration::from_secs(args.u64_or("serve-timeout", 630)),
        ..ReconnectCfg::default()
    };
    let mut trainer =
        MockTrainer::new(spec.dim, cfg.workers, spec.spread, spec.sigma, cfg.seed);
    println!("worker {id}: connecting to {addr}");
    let served = connect_worker_with_retry(
        addr.as_str(),
        id,
        &mut trainer,
        cfg.codec.build(),
        cfg.wire_codec,
        &retry,
    )?;
    println!("worker {id}: served {served} rounds, shut down cleanly");
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    let root = args.get_or("root", ".");
    let report = fedrecycle::lint::run_tree(Path::new(&root))?;
    let rendered = report.render();
    print!("{rendered}");
    if let Some(path) = args.get("report") {
        std::fs::write(path, &rendered)?;
    }
    anyhow::ensure!(
        report.files_scanned > 0,
        "no Rust sources found under --root {root} — run from the repo root"
    );
    anyhow::ensure!(
        report.is_clean(),
        "fedlint found {} violation(s)",
        report.violations.len()
    );
    Ok(())
}

/// `trace`: summarize a JSONL trace written by a `--trace` run.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("usage: fedrecycle trace <run.jsonl>"))?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    print!("{}", obs::sink::summarize(&text)?);
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env(args)?;
    let mut cfg = cfg_from_args(args)?;
    cfg.workers = 1;
    cfg.noniid = false;
    let epochs = args.usize_or("epochs", 20);
    let steps = args.usize_or("steps-per-epoch", 6);
    let meta = manifest.variant(&cfg.variant)?;
    let mut trainer = figures::common::make_trainer(&rt, &manifest, &cfg)?;
    let report = centralized_analysis(
        &mut trainer,
        meta.load_init()?,
        meta.segments.clone(),
        epochs,
        steps,
        cfg.eta as f32,
    )?;
    println!("{:>6} {:>5} {:>5} {:>12} {:>12}", "epoch", "N95", "N99", "test_loss", "metric");
    for e in &report.per_epoch {
        println!(
            "{:>6} {:>5} {:>5} {:>12.4} {:>12.4}",
            e.epoch, e.n95, e.n99, e.test_loss, e.test_metric
        );
    }
    println!("N99 fraction of epochs: {:.1}%", 100.0 * report.n99_fraction());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let scale = Scale::parse(&args.get_or("scale", "default"));
    let out = PathBuf::from(args.get_or("out", "results"));
    // `theory` needs no artifacts.
    if which == "theory" {
        return figures::theory::run(scale, &out);
    }
    let (rt, manifest) = load_env(args)?;
    let run_one = |id: &str| -> Result<()> {
        match id {
            "fig1" => figures::fig1::run(&rt, &manifest, scale, &out),
            "fig2" => figures::fig2::run(&rt, &manifest, scale, &out),
            "fig3" => figures::fig3::run(&rt, &manifest, scale, &out),
            "fig5" => figures::fig5::run(&rt, &manifest, scale, &out),
            "fig6" => figures::fig6::run(&rt, &manifest, scale, &out),
            "fig7" => figures::fig7::run(&rt, &manifest, scale, &out),
            "fig8" => figures::fig8::run(&rt, &manifest, scale, &out),
            "sampling" => figures::sampling::run(&rt, &manifest, scale, &out),
            "theory" => figures::theory::run(scale, &out),
            other => anyhow::bail!("unknown figure `{other}`"),
        }
    };
    if which == "all" {
        for id in [
            "fig1", "fig2", "fig3", "fig5", "fig6", "fig7", "fig8", "sampling",
            "theory",
        ] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
