//! `fedrecycle` — LBGM federated-learning launcher.
//!
//! Subcommands:
//!   info                          list artifact variants
//!   train [--config f.json] [..]  run one FL experiment arm
//!   analyze --variant V --dataset D   centralized gradient-space analysis
//!   figure <id|all> [--scale smoke|default|full] [--out results]
//!       ids: fig1 fig2 fig3 fig5 fig6 fig7 fig8 sampling theory
//!
//! Common flags for `train`: --variant --dataset --workers --rounds --tau
//!   --eta --delta --noniid true|false --codec identity|topk|topk_ef|atomo|
//!   signsgd --codec-fraction --codec-rank --sample-fraction --seed
//!   --parallelism seq|auto|<threads>  (round-engine concurrency; results
//!   are bit-identical across settings)

use std::path::{Path, PathBuf};

use anyhow::Result;

use fedrecycle::analysis::gradient_space::centralized_analysis;
use fedrecycle::config::{CodecKind, ExperimentConfig};
use fedrecycle::coordinator::Parallelism;
use fedrecycle::figures::{self, common::Scale};
use fedrecycle::metrics::write_csv;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_env(args: &Args) -> Result<(Runtime, Manifest)> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    let manifest = Manifest::load(&dir)?;
    let rt = Runtime::cpu()?;
    Ok((rt, manifest))
}

fn cfg_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_file(Path::new(path))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(v) = args.get("variant") {
        cfg.variant = v.into();
    }
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.into();
    }
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.rounds = args.usize_or("rounds", cfg.rounds);
    cfg.tau = args.usize_or("tau", cfg.tau);
    cfg.eta = args.f64_or("eta", cfg.eta);
    cfg.delta = args.f64_or("delta", cfg.delta);
    if let Some(v) = args.get("noniid") {
        cfg.noniid = v == "true" || v == "1";
    }
    cfg.labels_per_worker = args.usize_or("labels-per-worker", cfg.labels_per_worker);
    cfg.sample_fraction = args.f64_or("sample-fraction", cfg.sample_fraction);
    cfg.train_n = args.usize_or("train-n", cfg.train_n);
    cfg.test_n = args.usize_or("test-n", cfg.test_n);
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every);
    cfg.seed = args.u64_or("seed", cfg.seed);
    if let Some(name) = args.get("codec") {
        cfg.codec = CodecKind::parse(
            name,
            args.f64_or("codec-fraction", 0.1),
            args.usize_or("codec-rank", 2),
        )?;
    }
    if let Some(v) = args.get("parallelism") {
        cfg.parallelism = Parallelism::parse(v)?;
    }
    Ok(cfg)
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("info") => cmd_info(args),
        Some("train") => cmd_train(args),
        Some("analyze") => cmd_analyze(args),
        Some("figure") => cmd_figure(args),
        _ => {
            println!("usage: fedrecycle <info|train|analyze|figure> [flags]");
            println!("       fedrecycle figure all --scale default --out results");
            Ok(())
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env(args)?;
    println!("platform: {}", rt.platform());
    println!(
        "{:<18} {:<5} {:>10} {:>7} {:<22}",
        "variant", "task", "params", "batch", "x_shape"
    );
    for v in &manifest.variants {
        println!(
            "{:<18} {:<5} {:>10} {:>7} {:<22?}",
            v.name, v.task, v.param_count, v.batch, v.x_shape
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env(args)?;
    let cfg = cfg_from_args(args)?;
    println!(
        "train: variant={} dataset={} K={} T={} tau={} eta={} delta={} codec={:?} par={:?}",
        cfg.variant, cfg.dataset, cfg.workers, cfg.rounds, cfg.tau, cfg.eta,
        cfg.delta, cfg.codec, cfg.parallelism
    );
    let outc = figures::common::run_arm(&rt, &manifest, &cfg, &cfg.name.clone())?;
    println!(
        "done: final metric {:.4} | floats {:>12} | bits {:>14} | scalar msgs {:.1}%",
        outc.series.final_metric(),
        outc.ledger.total_floats,
        outc.ledger.total_bits,
        100.0 * outc.series.scalar_fraction()
    );
    println!("phase timings: {}", outc.timers.report());
    if let Some(out) = args.get("out") {
        write_csv(&Path::new(out).join(format!("{}.csv", cfg.name)), &[outc.series])?;
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let (rt, manifest) = load_env(args)?;
    let mut cfg = cfg_from_args(args)?;
    cfg.workers = 1;
    cfg.noniid = false;
    let epochs = args.usize_or("epochs", 20);
    let steps = args.usize_or("steps-per-epoch", 6);
    let meta = manifest.variant(&cfg.variant)?;
    let mut trainer = figures::common::make_trainer(&rt, &manifest, &cfg)?;
    let report = centralized_analysis(
        &mut trainer,
        meta.load_init()?,
        meta.segments.clone(),
        epochs,
        steps,
        cfg.eta as f32,
    )?;
    println!("{:>6} {:>5} {:>5} {:>12} {:>12}", "epoch", "N95", "N99", "test_loss", "metric");
    for e in &report.per_epoch {
        println!(
            "{:>6} {:>5} {:>5} {:>12.4} {:>12.4}",
            e.epoch, e.n95, e.n99, e.test_loss, e.test_metric
        );
    }
    println!("N99 fraction of epochs: {:.1}%", 100.0 * report.n99_fraction());
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let scale = Scale::parse(&args.get_or("scale", "default"));
    let out = PathBuf::from(args.get_or("out", "results"));
    // `theory` needs no artifacts.
    if which == "theory" {
        return figures::theory::run(scale, &out);
    }
    let (rt, manifest) = load_env(args)?;
    let run_one = |id: &str| -> Result<()> {
        match id {
            "fig1" => figures::fig1::run(&rt, &manifest, scale, &out),
            "fig2" => figures::fig2::run(&rt, &manifest, scale, &out),
            "fig3" => figures::fig3::run(&rt, &manifest, scale, &out),
            "fig5" => figures::fig5::run(&rt, &manifest, scale, &out),
            "fig6" => figures::fig6::run(&rt, &manifest, scale, &out),
            "fig7" => figures::fig7::run(&rt, &manifest, scale, &out),
            "fig8" => figures::fig8::run(&rt, &manifest, scale, &out),
            "sampling" => figures::sampling::run(&rt, &manifest, scale, &out),
            "theory" => figures::theory::run(scale, &out),
            other => anyhow::bail!("unknown figure `{other}`"),
        }
    };
    if which == "all" {
        for id in [
            "fig1", "fig2", "fig3", "fig5", "fig6", "fig7", "fig8", "sampling",
            "theory",
        ] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
