//! `sim::chaos` — the [`ChaosLink`] decorator that replays a [`FaultPlan`]
//! against a live [`Link`].
//!
//! The server wraps each worker's link in a `ChaosLink` (see
//! [`wrap_links`]); the decorator watches the downlink for `Round` frames
//! and, when the plan faults `(worker, t)`, swallows the broadcast (the
//! bytes are reported as sent — they "die in the network") and arms a
//! pending failure that the next `recv` on the link raises in the
//! fault-kind-specific way: an instant miss, a bounded straggler delay, a
//! connection-reset error, or a genuinely corrupted frame pushed through
//! the real wire decoder. Control-plane frames (handshake, shutdown) are
//! never intercepted, so a chaos deployment always tears down cleanly.
//!
//! [`FaultKind::Sever`] goes further than the other kinds: at its span
//! start the decorator *drops the real transport* (closing a TCP socket,
//! so the peer sees EOF and reconnects through the elastic server's
//! accept thread), and for the rest of the span it swallows broadcasts on
//! whatever link the rejoin re-seats — which is what keeps the absence
//! schedule deterministic even though reconnect timing is not.
//!
//! Cutting the round trip at the downlink is what keeps a faulted worker's
//! state frozen for the round (trainer stream, codec residuals, LBG) —
//! the invariant behind the bit-exact parity with a fault-restricted
//! sequential run; see the [`sim::fault`] module docs.
//!
//! [`sim::fault`]: super::fault

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::messages::{Payload, WorkerMsg, SCALAR_COST};
use crate::net::link::Link;
use crate::net::wire::{self, Frame};
use crate::obs::{record_to, Event, TraceHandle};
use crate::util::rng::Rng;

use super::fault::{FaultKind, FaultPlan};

/// Upper bound on an injected [`FaultKind::Delay`] sleep, so a hostile or
/// typo'd plan cannot stall a run for minutes per fault.
///
/// Like a real straggler, an injected delay burns the *shared* per-round
/// deadline while the server waits: with a deadline close to the plan's
/// total injected delay, healthy workers collected afterwards can miss it
/// too — realistic cascade behavior, but it breaks bit-parity with the
/// fault-restricted sequential reference. Keep `round_deadline` comfortably
/// above the largest per-round sum of injected delays when parity matters
/// (the in-process deployments' 120 s default vs. this 2 s cap gives a
/// wide margin).
pub const MAX_INJECTED_DELAY: Duration = Duration::from_millis(2_000);

/// A [`Link`] decorator that injects the scheduled faults of one worker.
pub struct ChaosLink {
    inner: Box<dyn Link>,
    worker: usize,
    plan: Arc<FaultPlan>,
    /// Armed by a swallowed downlink; consumed by the next `recv`.
    pending: Option<(u64, FaultKind)>,
    /// Armed by a nonblocking poll that hit a pending [`FaultKind::Delay`]:
    /// the round plus the wall-clock instant at which the injected
    /// straggler delay elapses. Until then `try_recv` reports "nothing
    /// yet" instead of sleeping — a pooled readiness thread must never be
    /// stalled by one worker's chaos schedule.
    delay_until: Option<(u64, std::time::Instant)>,
    /// Optional trace handle: transport teardowns at a sever-span start
    /// surface as diagnostic [`Event::Sever`] trace events.
    trace: Option<TraceHandle>,
}

/// Replacement transport for a severed connection: every operation fails.
/// Swapping a link's innards for this drops the real transport, which for
/// a `TcpLink` closes the socket — the peer sees EOF and its reconnect
/// loop takes over.
struct DeadLink;

impl Link for DeadLink {
    fn send_raw(&mut self, _bytes: &[u8]) -> Result<usize> {
        anyhow::bail!("chaos: connection severed")
    }

    fn recv(&mut self) -> Result<Frame> {
        anyhow::bail!("chaos: connection severed")
    }

    fn try_recv(&mut self) -> Result<Option<Frame>> {
        anyhow::bail!("chaos: connection severed")
    }

    fn set_recv_timeout(&mut self, _timeout: Option<Duration>) -> Result<()> {
        Ok(())
    }

    fn set_recv_limit(&mut self, _max_payload: usize) {}
}

impl ChaosLink {
    pub fn wrap(inner: Box<dyn Link>, worker: usize, plan: Arc<FaultPlan>) -> Self {
        Self::wrap_traced(inner, worker, plan, None)
    }

    /// [`ChaosLink::wrap`] with a trace handle, so a sever-span teardown
    /// is visible in the diagnostic trace stream.
    pub fn wrap_traced(
        inner: Box<dyn Link>,
        worker: usize,
        plan: Arc<FaultPlan>,
        trace: Option<TraceHandle>,
    ) -> Self {
        Self { inner, worker, plan, pending: None, delay_until: None, trace }
    }

    /// The fault-kind-specific receive failure for round `t`. Blocking
    /// callers sleep out an injected [`FaultKind::Delay`] here; the
    /// nonblocking path arms [`ChaosLink::delay_until`] instead and builds
    /// the final error with [`ChaosLink::fault_error`] directly.
    fn raise(&self, t: u64, kind: FaultKind) -> anyhow::Error {
        if let FaultKind::Delay { ms } = kind {
            std::thread::sleep(Duration::from_millis(ms).min(MAX_INJECTED_DELAY));
        }
        self.fault_error(t, kind)
    }

    /// The error a fault surfaces as, with no side effects (no sleeping).
    fn fault_error(&self, t: u64, kind: FaultKind) -> anyhow::Error {
        let w = self.worker;
        match kind {
            FaultKind::DropUplink => {
                anyhow::anyhow!("chaos: worker {w}'s round-{t} uplink was dropped")
            }
            FaultKind::Delay { .. } => {
                anyhow::anyhow!("chaos: worker {w} answered round {t} after the deadline")
            }
            FaultKind::Disconnect => {
                anyhow::anyhow!("chaos: connection to worker {w} reset (round {t})")
            }
            FaultKind::Sever => {
                anyhow::anyhow!("chaos: connection to worker {w} severed (round {t})")
            }
            FaultKind::CorruptFrame => {
                // Fabricate the frame the worker would plausibly have sent,
                // corrupt one deterministic payload byte, and push it
                // through the real decoder so the server handles an honest
                // checksum rejection.
                let msg = WorkerMsg {
                    worker: w,
                    round: t as usize,
                    payload: Payload::Scalar { rho: 0.0 },
                    cost: SCALAR_COST,
                    train_loss: 0.0,
                };
                let mut bytes = Frame::Update(msg).to_bytes();
                let mut rng =
                    Rng::new(self.plan.seed ^ ((w as u64) << 32) ^ t.wrapping_mul(0x9E37));
                let payload = bytes.len() - wire::HEADER_LEN - wire::CHECKSUM_LEN;
                let i = wire::HEADER_LEN + rng.below(payload.max(1));
                bytes[i] ^= 0x5A;
                let err = match Frame::from_bytes(&bytes) {
                    Err(e) => e,
                    Ok(_) => anyhow::anyhow!("corrupted frame unexpectedly decoded"),
                };
                err.context(format!(
                    "chaos: worker {w}'s round-{t} uplink frame arrived corrupted"
                ))
            }
        }
    }
}

impl Link for ChaosLink {
    fn send_raw(&mut self, bytes: &[u8]) -> Result<usize> {
        if let Some(t) = wire::peek_round(bytes) {
            if let Some(ev) = self.plan.fault_event(self.worker, t as usize) {
                let kind = ev.kind;
                // A sever tears the transport down for real — but only at
                // its span start: a link re-seated by a mid-span rejoin
                // must not be killed again (the worker reconnected early;
                // the plan's absence schedule is enforced by swallowing
                // below until the span ends).
                if kind == FaultKind::Sever && t as usize == ev.from {
                    record_to(
                        &self.trace,
                        Event::Sever { t: t as u32, worker: self.worker as u32 },
                    );
                    self.inner = Box::new(DeadLink);
                }
                // Swallow the broadcast: the caller's accounting sees the
                // bytes as sent, the peer never does.
                self.pending = Some((t, kind));
                return Ok(bytes.len());
            }
        }
        self.inner.send_raw(bytes)
    }

    fn recv(&mut self) -> Result<Frame> {
        if let Some((t, kind)) = self.pending.take() {
            return Err(self.raise(t, kind));
        }
        if let Some((t, due)) = self.delay_until.take() {
            // A poll armed this straggler's deadline; a blocking caller
            // sleeps out whatever is left of it.
            let now = std::time::Instant::now(); // lint: allow(determinism, "injected-delay pacing bounds waiting only, never ordering or arithmetic")
            if let Some(left) = due.checked_duration_since(now) {
                std::thread::sleep(left);
            }
            return Err(self.fault_error(t, FaultKind::Delay { ms: 0 }));
        }
        self.inner.recv()
    }

    fn try_recv(&mut self) -> Result<Option<Frame>> {
        if let Some((t, kind)) = self.pending.take() {
            if let FaultKind::Delay { ms } = kind {
                // Convert the straggler sleep into an armed deadline: the
                // poll reports "nothing yet" until the injected delay has
                // elapsed, then fails exactly like the blocking path —
                // without ever stalling the polling thread.
                let due = std::time::Instant::now() // lint: allow(determinism, "injected-delay pacing bounds waiting only, never ordering or arithmetic")
                    + Duration::from_millis(ms).min(MAX_INJECTED_DELAY);
                self.delay_until = Some((t, due));
                return Ok(None);
            }
            return Err(self.fault_error(t, kind));
        }
        if let Some((t, due)) = self.delay_until {
            let now = std::time::Instant::now(); // lint: allow(determinism, "injected-delay pacing bounds waiting only, never ordering or arithmetic")
            if now < due {
                return Ok(None);
            }
            self.delay_until = None;
            return Err(self.fault_error(t, FaultKind::Delay { ms: 0 }));
        }
        self.inner.try_recv()
    }

    fn set_recv_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.inner.set_recv_timeout(timeout)
    }

    fn set_recv_limit(&mut self, max_payload: usize) {
        self.inner.set_recv_limit(max_payload);
    }
}

/// Wrap a full set of server-side worker links (`links[w]` is worker w's
/// connection) in [`ChaosLink`]s replaying `plan`.
pub fn wrap_links(links: Vec<Box<dyn Link>>, plan: &FaultPlan) -> Vec<Box<dyn Link>> {
    wrap_links_traced(links, plan, None)
}

/// [`wrap_links`] with a shared trace handle (cloned into every
/// decorator), so sever teardowns land in the diagnostic trace stream.
pub fn wrap_links_traced(
    links: Vec<Box<dyn Link>>,
    plan: &FaultPlan,
    trace: Option<TraceHandle>,
) -> Vec<Box<dyn Link>> {
    let plan = Arc::new(plan.clone());
    links
        .into_iter()
        .enumerate()
        .map(|(w, inner)| {
            Box::new(ChaosLink::wrap_traced(inner, w, Arc::clone(&plan), trace.clone()))
                as Box<dyn Link>
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::MemLink;
    use crate::sim::fault::FaultEvent;

    fn plan(events: Vec<FaultEvent>) -> Arc<FaultPlan> {
        Arc::new(FaultPlan { seed: 3, events, profiles: Vec::new() })
    }

    #[test]
    fn clean_rounds_pass_through_untouched() {
        let (srv, mut wrk) = MemLink::pair();
        let mut chaos = ChaosLink::wrap(Box::new(srv), 0, plan(Vec::new()));
        let sent = chaos.send(&Frame::Round { t: 0, theta: vec![1.0, 2.0] }).unwrap();
        match wrk.recv().unwrap() {
            Frame::Round { t, theta } => {
                assert_eq!(t, 0);
                assert_eq!(theta, vec![1.0, 2.0]);
            }
            other => panic!("wrong frame {other:?}"),
        }
        assert_eq!(sent, Frame::Round { t: 0, theta: vec![1.0, 2.0] }.wire_bytes());
        // Uplink flows back normally.
        wrk.send(&Frame::Shutdown).unwrap();
        assert!(matches!(chaos.recv().unwrap(), Frame::Shutdown));
    }

    #[test]
    fn faulted_round_swallows_downlink_and_fails_uplink() {
        let (srv, mut wrk) = MemLink::pair();
        let ev = FaultEvent { worker: 1, from: 2, until: 3, kind: FaultKind::DropUplink };
        let mut chaos = ChaosLink::wrap(Box::new(srv), 1, plan(vec![ev]));
        // Round 2 is faulted: the send reports success but nothing arrives.
        let encoded = Frame::Round { t: 2, theta: vec![0.5] }.to_bytes();
        assert_eq!(chaos.send_raw(&encoded).unwrap(), encoded.len());
        wrk.set_recv_timeout(Some(Duration::from_millis(20))).unwrap();
        assert!(wrk.recv().is_err(), "swallowed frame reached the worker");
        // The armed fault fires on the next server-side recv...
        let err = chaos.recv().unwrap_err().to_string();
        assert!(err.contains("dropped"), "{err}");
        // ...exactly once: the link is clean again afterwards.
        wrk.send(&Frame::Hello { worker: 1, dim: 1 }).unwrap();
        assert!(matches!(chaos.recv().unwrap(), Frame::Hello { .. }));
    }

    #[test]
    fn non_round_frames_are_never_intercepted() {
        let (srv, mut wrk) = MemLink::pair();
        let ev = FaultEvent { worker: 0, from: 0, until: 100, kind: FaultKind::Disconnect };
        let mut chaos = ChaosLink::wrap(Box::new(srv), 0, plan(vec![ev]));
        // Shutdown passes even though every round is inside the span.
        chaos.send(&Frame::Shutdown).unwrap();
        assert!(matches!(wrk.recv().unwrap(), Frame::Shutdown));
    }

    #[test]
    fn sever_tears_down_the_transport_at_span_start_only() {
        let (srv, mut wrk) = MemLink::pair();
        let ev = FaultEvent { worker: 0, from: 1, until: 3, kind: FaultKind::Sever };
        let mut chaos = ChaosLink::wrap(Box::new(srv), 0, plan(vec![ev]));
        // Round 0 is clean.
        chaos.send(&Frame::Round { t: 0, theta: vec![1.0] }).unwrap();
        assert!(matches!(wrk.recv().unwrap(), Frame::Round { t: 0, .. }));
        // Round 1 starts the span: the broadcast is swallowed AND the real
        // transport dies — the peer sees a hangup, not silence.
        chaos.send(&Frame::Round { t: 1, theta: vec![1.0] }).unwrap();
        assert!(wrk.recv().is_err(), "severed peer still receiving");
        let err = chaos.recv().unwrap_err().to_string();
        assert!(err.contains("severed"), "{err}");
        // The decorator's transport stays dead afterwards (the worker must
        // come back through a fresh link, not this one).
        assert!(chaos.recv().is_err());

        // A link re-seated mid-span (fresh ChaosLink, same plan) swallows
        // without killing: round 2 is still inside [1, 3).
        let (srv2, mut wrk2) = MemLink::pair();
        let ev = FaultEvent { worker: 0, from: 1, until: 3, kind: FaultKind::Sever };
        let mut reseated = ChaosLink::wrap(Box::new(srv2), 0, plan(vec![ev]));
        let encoded = Frame::Round { t: 2, theta: vec![1.0] }.to_bytes();
        assert_eq!(reseated.send_raw(&encoded).unwrap(), encoded.len());
        assert!(reseated.recv().is_err(), "swallowed round must be an absence");
        // After the span the re-seated link flows normally.
        reseated.send(&Frame::Round { t: 3, theta: vec![2.0] }).unwrap();
        assert!(matches!(wrk2.recv().unwrap(), Frame::Round { t: 3, .. }));
        wrk2.send(&Frame::Hello { worker: 0, dim: 1 }).unwrap();
        assert!(matches!(reseated.recv().unwrap(), Frame::Hello { .. }));
    }

    #[test]
    fn try_recv_raises_faults_without_sleeping() {
        // Non-delay fault: the poll fails immediately, once.
        let (srv, _wrk) = MemLink::pair();
        let ev = FaultEvent { worker: 1, from: 0, until: 1, kind: FaultKind::DropUplink };
        let mut chaos = ChaosLink::wrap(Box::new(srv), 1, plan(vec![ev]));
        chaos.send(&Frame::Round { t: 0, theta: vec![0.5] }).unwrap();
        let err = chaos.try_recv().unwrap_err().to_string();
        assert!(err.contains("dropped"), "{err}");
        assert!(chaos.try_recv().unwrap().is_none(), "fault fired twice");

        // Delay fault: polls stay Ok(None) while the injected straggler
        // delay runs, then fail — the polling thread itself never sleeps.
        let (srv, _wrk) = MemLink::pair();
        let ev = FaultEvent { worker: 2, from: 0, until: 1, kind: FaultKind::Delay { ms: 60 } };
        let mut chaos = ChaosLink::wrap(Box::new(srv), 2, plan(vec![ev]));
        chaos.send(&Frame::Round { t: 0, theta: vec![0.5] }).unwrap();
        let start = std::time::Instant::now();
        assert!(chaos.try_recv().unwrap().is_none(), "delay must arm, not fail");
        assert!(
            start.elapsed() < Duration::from_millis(50),
            "try_recv slept out the injected delay"
        );
        let deadline = start + Duration::from_secs(10);
        let err = loop {
            match chaos.try_recv() {
                Ok(None) => {
                    assert!(std::time::Instant::now() < deadline, "delay never elapsed");
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(Some(f)) => panic!("unexpected frame {f:?}"),
                Err(e) => break e.to_string(),
            }
        };
        assert!(err.contains("after the deadline"), "{err}");
        assert!(
            start.elapsed() >= Duration::from_millis(60),
            "armed delay elapsed early"
        );
    }

    #[test]
    fn corrupt_fault_surfaces_a_real_decode_error() {
        let (srv, _wrk) = MemLink::pair();
        let ev = FaultEvent { worker: 2, from: 0, until: 1, kind: FaultKind::CorruptFrame };
        let mut chaos = ChaosLink::wrap(Box::new(srv), 2, plan(vec![ev]));
        let encoded = Frame::Round { t: 0, theta: vec![0.0; 4] }.to_bytes();
        chaos.send_raw(&encoded).unwrap();
        let err = format!("{:#}", chaos.recv().unwrap_err());
        assert!(err.contains("corrupted"), "{err}");
        // The cause chain carries the codec's genuine rejection.
        assert!(
            err.contains("checksum") || err.contains("truncated") || err.contains("payload"),
            "no decode cause in: {err}"
        );
    }
}
