//! Deterministic chaos harness: seeded fault injection for the networked
//! deployment.
//!
//! The `net` layer turns the simulation into a real client/server system;
//! `sim` turns that system into one you can *torture reproducibly*:
//!
//! * [`fault`] — the [`FaultPlan`] DSL: per-worker, per-round events
//!   (drop uplink, delay past the deadline, silently-healing disconnect,
//!   corrupt frame, and `sever` — a real transport teardown whose
//!   recovery exercises the elastic server's `Rejoin` path end to end)
//!   plus per-worker flaky-link profiles, loadable from JSON
//!   (`--faults plan.json`), buildable from [`testkit::scenarios`], or
//!   generated from a seed.
//! * [`chaos`] — [`ChaosLink`], a [`Link`] decorator that replays a plan
//!   against live links.
//!
//! Combined with the round engines' partial-participation aggregation
//! (a round commits with whichever workers made the deadline, FedAvg
//! weights renormalized over the arrived set), the same plan + seed
//! produce bit-identical runs on every transport — sequential, threaded,
//! `MemLink`, and TCP loopback (`tests/chaos_recovery.rs`).
//!
//! [`Link`]: crate::net::Link
//! [`testkit::scenarios`]: crate::testkit::scenarios

pub mod chaos;
pub mod fault;

pub use chaos::{wrap_links, wrap_links_traced, ChaosLink};
pub use fault::{ChaosSpec, FaultEvent, FaultKind, FaultPlan, WorkerProfile};
