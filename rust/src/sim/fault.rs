//! `sim::fault` — the seeded, fully deterministic fault-injection DSL.
//!
//! A [`FaultPlan`] is a concrete, replayable schedule of per-worker,
//! per-round network misbehavior: which worker misses which rounds, and by
//! what mechanism ([`FaultKind`]). Plans are plain data — loadable from
//! JSON (`--faults plan.json`), buildable from the
//! [`testkit::scenarios`] helpers, or generated pseudo-randomly from a
//! seed ([`FaultPlan::random`]) — so the *same plan + same seed* always
//! reproduces the *same run*, bit for bit, on every engine.
//!
//! # Round-absence semantics
//!
//! A fault for `(worker, round)` removes that worker from that round
//! **entirely**: the chaos layer cuts the round trip at its earliest point
//! (the downlink `Round` frame), so the worker never trains the faulted
//! round and none of its state — trainer RNG streams, codec residuals, or
//! the LBGM look-back gradient — advances. This is what keeps the
//! worker-side and server-side LBG copies coherent across absences (a
//! dropped *refresh* would otherwise desync them silently), and what makes
//! a chaos run bit-identical to a sequential run restricted to the
//! fault-free participants (asserted by `tests/chaos_recovery.rs`). The
//! [`FaultKind`] variants differ in the *server-visible mechanism* of the
//! miss: an instant silent drop, a deadline-style delay, a
//! connection-reset error, or a genuinely corrupted frame that must be
//! rejected by the wire codec.
//!
//! # JSON schema
//!
//! ```json
//! {
//!   "seed": 7,
//!   "events": [
//!     {"kind": "drop_uplink",   "worker": 2, "from": 2, "until": 4},
//!     {"kind": "delay",         "worker": 1, "round": 5, "ms": 50},
//!     {"kind": "disconnect",    "worker": 0, "from": 3, "until": 6},
//!     {"kind": "corrupt_frame", "worker": 3, "round": 1},
//!     {"kind": "sever",         "worker": 1, "from": 4, "until": 6}
//!   ],
//!   "profiles": [
//!     {"worker": 0, "latency_us": 200, "bytes_per_sec": 1000000, "loss": 0.2}
//!   ]
//! }
//! ```
//!
//! `from`/`until` bound a half-open round span `[from, until)`; `"round": t`
//! is shorthand for `from = t, until = t + 1`. `profiles` attach a
//! deterministic [`LinkProfile`] (latency/bandwidth/loss shaping, wall-clock
//! only) to a worker's uplink in the `MemLink` deployment.
//!
//! [`testkit::scenarios`]: crate::testkit::scenarios

use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::net::link::LinkProfile;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;

/// Mechanism by which a worker misses a round (see the module docs for the
/// shared round-absence semantics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// The uplink update silently never arrives; the server sees an
    /// instant "nothing came" failure.
    DropUplink,
    /// The update misses the deadline: the chaos layer waits `ms`
    /// milliseconds (bounded by [`MAX_INJECTED_DELAY`]) before reporting
    /// the miss, modeling a straggler that answers too late. The wait
    /// burns the server's shared round deadline like a real straggler
    /// would — keep the deadline well above the per-round sum of injected
    /// delays when bit-parity with the sequential reference matters.
    ///
    /// [`MAX_INJECTED_DELAY`]: super::chaos::MAX_INJECTED_DELAY
    Delay { ms: u64 },
    /// The link behaves as reset for the span: sends are swallowed and
    /// receives fail with a connection-reset-style error. Frames flow
    /// again after the span ends ("rejoin").
    Disconnect,
    /// The uplink frame arrives with a corrupted payload byte; the server
    /// must reject it through the wire codec's checksum and carry on.
    CorruptFrame,
    /// The worker's *transport* is genuinely torn down at round `from`
    /// (the server-side socket closes, so a TCP peer sees EOF) and the
    /// worker is absent for `[from, until)`. Unlike [`Disconnect`], which
    /// models reset-style errors on a link that silently heals, `Sever`
    /// exercises the elastic recovery path end to end: the client's
    /// reconnect loop re-handshakes with `Frame::Rejoin`, the server
    /// re-seats the link, and the worker's first post-rejoin uplink is a
    /// forced full refresh (the reconciliation that keeps both LBG copies
    /// coherent). TCP deployments only — `MemLink` workers cannot
    /// reconnect — and the worker must be *sampled* at round `from` for
    /// the teardown to trigger (the chaos layer cuts on the downlink).
    /// The in-memory engines model the same schedule by forcing the
    /// worker's refresh at round `until` (see `FaultPlan::rejoins_at`),
    /// which is what keeps a severed TCP run bit-identical to the
    /// sequential reference.
    ///
    /// [`Disconnect`]: FaultKind::Disconnect
    Sever,
}

impl FaultKind {
    /// The JSON spelling of this kind.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::DropUplink => "drop_uplink",
            FaultKind::Delay { .. } => "delay",
            FaultKind::Disconnect => "disconnect",
            FaultKind::CorruptFrame => "corrupt_frame",
            FaultKind::Sever => "sever",
        }
    }
}

/// One scheduled fault: `worker` misses rounds `[from, until)` via `kind`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub worker: usize,
    /// First faulted round (inclusive).
    pub from: usize,
    /// End of the faulted span (exclusive).
    pub until: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Does this event remove `worker` from `round`?
    pub fn hits(&self, worker: usize, round: usize) -> bool {
        self.worker == worker && (self.from..self.until).contains(&round)
    }
}

/// Deterministic per-worker link shaping, attached to a plan (wall-clock
/// only; results are unaffected — see [`SimLink`]).
///
/// [`SimLink`]: crate::net::link::SimLink
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerProfile {
    pub worker: usize,
    pub latency_us: u64,
    pub bytes_per_sec: u64,
    pub loss: f64,
}

/// A complete, replayable fault schedule (see the module docs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Seeds the deterministic streams derived *from* the plan (corrupt
    /// byte positions, per-worker loss streams). Also recorded so a plan
    /// generated by [`FaultPlan::random`] documents its own provenance.
    pub seed: u64,
    pub events: Vec<FaultEvent>,
    pub profiles: Vec<WorkerProfile>,
}

/// Knobs for [`FaultPlan::random`]: per-round probabilities of each fault
/// kind (cumulative sum should stay below 1).
#[derive(Clone, Copy, Debug)]
pub struct ChaosSpec {
    pub p_drop: f64,
    pub p_delay: f64,
    pub p_disconnect: f64,
    pub p_corrupt: f64,
    /// Longest disconnect span, in rounds (min 1).
    pub max_span: usize,
    /// Injected delay duration for [`FaultKind::Delay`] events.
    pub delay_ms: u64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        Self {
            p_drop: 0.08,
            p_delay: 0.05,
            p_disconnect: 0.04,
            p_corrupt: 0.03,
            max_span: 3,
            delay_ms: 1,
        }
    }
}

impl FaultPlan {
    /// A plan with no events at all (chaos off).
    pub fn empty() -> Self {
        Self::default()
    }

    /// The first fault event scheduled for `(worker, round)`, if any.
    pub fn fault_event(&self, worker: usize, round: usize) -> Option<&FaultEvent> {
        self.events.iter().find(|e| e.hits(worker, round))
    }

    /// The first fault scheduled for `(worker, round)`, if any.
    pub fn fault(&self, worker: usize, round: usize) -> Option<FaultKind> {
        self.fault_event(worker, round).map(|e| e.kind)
    }

    /// Workers whose severed connection is scheduled to be restored at
    /// round `t` (a [`FaultKind::Sever`] span `[from, until)` with
    /// `until == t`). The round engines force these workers' next uplink
    /// to be a full refresh and count a rejoin — the in-memory mirror of
    /// the client-side reconnect reconciliation.
    pub fn rejoins_at(&self, t: usize) -> impl Iterator<Item = usize> + '_ {
        self.events
            .iter()
            .filter(move |e| e.kind == FaultKind::Sever && e.until == t)
            .map(|e| e.worker)
    }

    /// Number of sever spans for `worker` whose rejoin is due at or before
    /// round `t` — what the elastic server compares against its observed
    /// rejoin count when deciding whether a round start should wait for a
    /// returning worker.
    pub fn rejoins_due(&self, worker: usize, t: usize) -> usize {
        self.events
            .iter()
            .filter(|e| e.worker == worker && e.kind == FaultKind::Sever && e.until <= t)
            .count()
    }

    /// Is `worker` absent from `round` under this plan?
    pub fn absent(&self, worker: usize, round: usize) -> bool {
        self.fault(worker, round).is_some()
    }

    /// Split a sampled participant set into `(arrived, absent)` for one
    /// round, both preserving the input order.
    pub fn split_round(&self, participants: &[usize], round: usize) -> (Vec<usize>, Vec<usize>) {
        let mut arrived = Vec::with_capacity(participants.len());
        let mut absent = Vec::new();
        for &w in participants {
            if self.absent(w, round) {
                absent.push(w);
            } else {
                arrived.push(w);
            }
        }
        (arrived, absent)
    }

    /// The link-shaping profile attached to `worker`, if any, with a
    /// per-worker loss stream derived from the plan seed.
    pub fn profile_for(&self, worker: usize) -> Option<LinkProfile> {
        self.profiles.iter().find(|p| p.worker == worker).map(|p| LinkProfile {
            latency: Duration::from_micros(p.latency_us),
            bytes_per_sec: p.bytes_per_sec,
            loss: p.loss,
            seed: self.seed ^ worker as u64,
        })
    }

    /// Total number of faulted `(worker, round)` slots in `[0, rounds)`
    /// for a `workers`-wide federation (diagnostics; the engines count the
    /// subset that intersects the sampled participants).
    pub fn scheduled_slots(&self, workers: usize, rounds: usize) -> usize {
        (0..workers)
            .map(|w| (0..rounds).filter(|&t| self.absent(w, t)).count())
            .sum() // lint: allow(reduction_order, "integer slot count: usize addition is associative")
    }

    /// Generate a concrete plan pseudo-randomly from a seed: each worker
    /// walks the round range, drawing at most one event per position, with
    /// disconnects spanning up to `spec.max_span` rounds. Deterministic:
    /// the same `(seed, workers, rounds, spec)` always yields the same
    /// plan.
    pub fn random(seed: u64, workers: usize, rounds: usize, spec: &ChaosSpec) -> Self {
        let mut rng = Rng::new(seed);
        let mut events = Vec::new();
        for w in 0..workers {
            let mut t = 0usize;
            while t < rounds {
                let u = rng.next_f64();
                let c1 = spec.p_drop;
                let c2 = c1 + spec.p_delay;
                let c3 = c2 + spec.p_disconnect;
                let c4 = c3 + spec.p_corrupt;
                let (kind, span) = if u < c1 {
                    (Some(FaultKind::DropUplink), 1)
                } else if u < c2 {
                    (Some(FaultKind::Delay { ms: spec.delay_ms }), 1)
                } else if u < c3 {
                    (Some(FaultKind::Disconnect), 1 + rng.below(spec.max_span.max(1)))
                } else if u < c4 {
                    (Some(FaultKind::CorruptFrame), 1)
                } else {
                    (None, 1)
                };
                if let Some(kind) = kind {
                    events.push(FaultEvent {
                        worker: w,
                        from: t,
                        until: (t + span).min(rounds),
                        kind,
                    });
                }
                t += span;
            }
        }
        Self { seed, events, profiles: Vec::new() }
    }

    // -- JSON ---------------------------------------------------------------

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading fault plan {}", path.display()))?;
        let j = Json::parse(&text).context("parsing fault plan JSON")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let mut events = Vec::new();
        if let Some(items) = j.get("events").and_then(Json::as_arr) {
            for e in items {
                events.push(event_from_json(e)?);
            }
        }
        let mut profiles = Vec::new();
        if let Some(items) = j.get("profiles").and_then(Json::as_arr) {
            for p in items {
                profiles.push(WorkerProfile {
                    worker: p.req_usize("worker")?,
                    latency_us: p.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                    bytes_per_sec: p
                        .get("bytes_per_sec")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64,
                    loss: p.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
                });
            }
        }
        Ok(Self { seed, events, profiles })
    }

    pub fn to_json(&self) -> Json {
        let events = self.events.iter().map(|e| {
            let mut fields = vec![
                ("kind", s(e.kind.name())),
                ("worker", num(e.worker as f64)),
                ("from", num(e.from as f64)),
                ("until", num(e.until as f64)),
            ];
            if let FaultKind::Delay { ms } = e.kind {
                fields.push(("ms", num(ms as f64)));
            }
            obj(fields)
        });
        let profiles = self.profiles.iter().map(|p| {
            obj(vec![
                ("worker", num(p.worker as f64)),
                ("latency_us", num(p.latency_us as f64)),
                ("bytes_per_sec", num(p.bytes_per_sec as f64)),
                ("loss", num(p.loss)),
            ])
        });
        obj(vec![
            ("seed", num(self.seed as f64)),
            ("events", arr(events)),
            ("profiles", arr(profiles)),
        ])
    }
}

fn event_from_json(e: &Json) -> Result<FaultEvent> {
    let worker = e.req_usize("worker")?;
    let (from, until) = if let Some(r) = e.get("round").and_then(Json::as_usize) {
        (r, r + 1)
    } else {
        let from = e.req_usize("from")?;
        let until = e.req_usize("until")?;
        anyhow::ensure!(from < until, "fault span [{from}, {until}) is empty");
        (from, until)
    };
    let kind = match e.req_str("kind")? {
        "drop_uplink" => FaultKind::DropUplink,
        "delay" => FaultKind::Delay {
            ms: e.get("ms").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        },
        "disconnect" => FaultKind::Disconnect,
        "corrupt_frame" => FaultKind::CorruptFrame,
        "sever" => FaultKind::Sever,
        other => anyhow::bail!("unknown fault kind `{other}`"),
    };
    Ok(FaultEvent { worker, from, until, kind })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_hit_their_span_only() {
        let e = FaultEvent { worker: 2, from: 3, until: 5, kind: FaultKind::DropUplink };
        assert!(!e.hits(2, 2));
        assert!(e.hits(2, 3));
        assert!(e.hits(2, 4));
        assert!(!e.hits(2, 5));
        assert!(!e.hits(1, 3));
    }

    #[test]
    fn split_round_partitions_in_order() {
        let plan = FaultPlan {
            seed: 0,
            events: vec![FaultEvent {
                worker: 1,
                from: 0,
                until: 2,
                kind: FaultKind::Disconnect,
            }],
            profiles: Vec::new(),
        };
        let (arrived, absent) = plan.split_round(&[0, 1, 2], 1);
        assert_eq!(arrived, vec![0, 2]);
        assert_eq!(absent, vec![1]);
        let (arrived, absent) = plan.split_round(&[0, 1, 2], 2);
        assert_eq!(arrived, vec![0, 1, 2]);
        assert!(absent.is_empty());
        assert_eq!(plan.scheduled_slots(3, 4), 2);
    }

    #[test]
    fn random_plans_are_deterministic_per_seed() {
        let spec = ChaosSpec::default();
        let a = FaultPlan::random(9, 5, 20, &spec);
        let b = FaultPlan::random(9, 5, 20, &spec);
        assert_eq!(a, b);
        let c = FaultPlan::random(10, 5, 20, &spec);
        assert_ne!(a, c, "different seeds produced identical plans");
        // Every event stays inside the round range.
        assert!(a.events.iter().all(|e| e.from < e.until && e.until <= 20));
    }

    #[test]
    fn json_round_trip_preserves_the_plan() {
        let plan = FaultPlan {
            seed: 7,
            events: vec![
                FaultEvent { worker: 2, from: 2, until: 4, kind: FaultKind::DropUplink },
                FaultEvent { worker: 1, from: 5, until: 6, kind: FaultKind::Delay { ms: 50 } },
                FaultEvent { worker: 0, from: 3, until: 6, kind: FaultKind::Disconnect },
                FaultEvent { worker: 3, from: 1, until: 2, kind: FaultKind::CorruptFrame },
                FaultEvent { worker: 2, from: 7, until: 9, kind: FaultKind::Sever },
            ],
            profiles: vec![WorkerProfile {
                worker: 0,
                latency_us: 200,
                bytes_per_sec: 1_000_000,
                loss: 0.2,
            }],
        };
        let text = Json::to_string(&plan.to_json());
        let back = FaultPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn json_round_shorthand_and_errors() {
        let j = Json::parse(
            r#"{"events":[{"kind":"corrupt_frame","worker":3,"round":1}]}"#,
        )
        .unwrap();
        let plan = FaultPlan::from_json(&j).unwrap();
        assert_eq!(plan.events, vec![FaultEvent {
            worker: 3,
            from: 1,
            until: 2,
            kind: FaultKind::CorruptFrame,
        }]);
        assert!(plan.absent(3, 1));
        assert!(!plan.absent(3, 2));

        let bad = Json::parse(
            r#"{"events":[{"kind":"gremlins","worker":0,"round":0}]}"#,
        )
        .unwrap();
        assert!(FaultPlan::from_json(&bad).is_err());
        let empty_span = Json::parse(
            r#"{"events":[{"kind":"delay","worker":0,"from":3,"until":3}]}"#,
        )
        .unwrap();
        assert!(FaultPlan::from_json(&empty_span).is_err());
    }

    #[test]
    fn rejoins_at_reports_sever_span_ends_only() {
        let plan = FaultPlan {
            seed: 0,
            events: vec![
                FaultEvent { worker: 1, from: 2, until: 4, kind: FaultKind::Sever },
                FaultEvent { worker: 3, from: 3, until: 4, kind: FaultKind::Sever },
                // A plain disconnect heals silently: no rejoin scheduled.
                FaultEvent { worker: 0, from: 2, until: 4, kind: FaultKind::Disconnect },
            ],
            profiles: Vec::new(),
        };
        assert_eq!(plan.rejoins_at(4).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(plan.rejoins_at(2).count(), 0);
        assert_eq!(plan.rejoins_at(3).count(), 0);
        // Severed rounds are ordinary absences for the round engines.
        assert!(plan.absent(1, 2) && plan.absent(1, 3) && !plan.absent(1, 4));
        assert_eq!(plan.fault_event(1, 2).unwrap().kind, FaultKind::Sever);
    }

    #[test]
    fn profiles_resolve_with_plan_seed() {
        let plan = FaultPlan {
            seed: 11,
            events: Vec::new(),
            profiles: vec![WorkerProfile {
                worker: 2,
                latency_us: 100,
                bytes_per_sec: 500,
                loss: 0.1,
            }],
        };
        let p = plan.profile_for(2).unwrap();
        assert_eq!(p.latency, Duration::from_micros(100));
        assert_eq!(p.bytes_per_sec, 500);
        assert_eq!(p.seed, 11 ^ 2);
        assert!(plan.profile_for(0).is_none());
    }
}
