//! Synthetic byte-level corpus for the end-to-end transformer LM driver.
//!
//! A deterministic order-2 Markov source over a 64-symbol alphabet with
//! sparse transition structure: learnable (far from uniform entropy) yet
//! non-trivial, so the FL-trained LM's loss curve in the e2e example is a
//! meaningful convergence signal.

use crate::util::rng::Rng;

/// Token source + sequence batcher for the LM task.
pub struct MarkovCorpus {
    pub vocab: usize,
    tokens: Vec<i32>,
}

impl MarkovCorpus {
    /// Generate `n_tokens` tokens from a seeded sparse order-2 chain.
    pub fn generate(vocab: usize, n_tokens: usize, seed: u64) -> Self {
        assert!(vocab >= 4);
        let mut rng = Rng::new(seed);
        // For each (prev2, prev1) context: 4 candidate successors + weights.
        let n_ctx = vocab * vocab;
        let mut succ = Vec::with_capacity(n_ctx * 4);
        for _ in 0..n_ctx * 4 {
            succ.push(rng.below(vocab) as i32);
        }
        let mut tokens = Vec::with_capacity(n_tokens);
        let (mut p2, mut p1) = (0usize, 1usize);
        for _ in 0..n_tokens {
            let ctx = p2 * vocab + p1;
            // Zipf-ish pick among the 4 successors: 0.55/0.25/0.15/0.05.
            let u = rng.next_f64();
            let pick = if u < 0.55 {
                0
            } else if u < 0.80 {
                1
            } else if u < 0.95 {
                2
            } else {
                3
            };
            let t = succ[ctx * 4 + pick];
            tokens.push(t);
            p2 = p1;
            p1 = t as usize;
        }
        Self { vocab, tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Contiguous shard boundaries for `k` workers (token ranges).
    pub fn shard_ranges(&self, k: usize) -> Vec<(usize, usize)> {
        let per = self.tokens.len() / k;
        (0..k)
            .map(|w| {
                let lo = w * per;
                let hi = if w + 1 == k { self.tokens.len() } else { lo + per };
                (lo, hi)
            })
            .collect()
    }

    /// Sample a (x, y) LM batch from a token range: x = seq, y = next-token.
    pub fn sample_batch(
        &self,
        range: (usize, usize),
        batch: usize,
        seq: usize,
        rng: &mut Rng,
        out_x: &mut Vec<i32>,
        out_y: &mut Vec<i32>,
    ) {
        out_x.clear();
        out_y.clear();
        let (lo, hi) = range;
        assert!(hi - lo > seq + 1, "shard too small for seq len");
        for _ in 0..batch {
            let start = lo + rng.below(hi - lo - seq - 1);
            out_x.extend_from_slice(&self.tokens[start..start + seq]);
            out_y.extend_from_slice(&self.tokens[start + 1..start + seq + 1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_vocab() {
        let a = MarkovCorpus::generate(64, 10_000, 7);
        let b = MarkovCorpus::generate(64, 10_000, 7);
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn lower_conditional_entropy_than_uniform() {
        // The chain is order-2 with <=4 successors per context, so the
        // conditional next-token entropy given the previous token must sit
        // well below the uniform log2(64) = 6 bits (this is exactly the
        // structure the e2e transformer LM learns).
        let c = MarkovCorpus::generate(64, 200_000, 3);
        let v = c.vocab;
        use std::collections::HashMap;
        let mut trigram: HashMap<(i32, i32, i32), usize> = HashMap::new();
        let mut ctx: HashMap<(i32, i32), usize> = HashMap::new();
        for w in c.tokens.windows(3) {
            *trigram.entry((w[0], w[1], w[2])).or_default() += 1;
            *ctx.entry((w[0], w[1])).or_default() += 1;
        }
        let n = (c.tokens.len() - 2) as f64;
        // H(T | ctx) = -sum_{ctx,t} p(ctx,t) log2 p(t | ctx)
        let mut h_cond = 0f64;
        for ((p2, p1, _t), cnt) in &trigram {
            let q = *cnt as f64 / ctx[&(*p2, *p1)] as f64;
            h_cond -= (*cnt as f64 / n) * q.log2();
        }
        // Each seen context has <= 4 successors with 0.55/0.25/0.15/0.05
        // weights (~1.5 bits), far below the uniform log2(64)=6.
        assert!(h_cond < 3.0, "order-2 conditional entropy {h_cond} (v={v})");
    }

    #[test]
    fn batches_shift_by_one() {
        let c = MarkovCorpus::generate(16, 5_000, 1);
        let mut rng = Rng::new(0);
        let (mut x, mut y) = (Vec::new(), Vec::new());
        c.sample_batch((0, 5_000), 4, 32, &mut rng, &mut x, &mut y);
        assert_eq!(x.len(), 4 * 32);
        assert_eq!(y.len(), 4 * 32);
        // y is x shifted by one within each row.
        for row in 0..4 {
            for t in 0..31 {
                assert_eq!(x[row * 32 + t + 1], y[row * 32 + t]);
            }
        }
    }

    #[test]
    fn shards_cover() {
        let c = MarkovCorpus::generate(16, 1000, 2);
        let r = c.shard_ranges(3);
        assert_eq!(r[0].0, 0);
        assert_eq!(r[2].1, 1000);
        assert_eq!(r[0].1, r[1].0);
    }
}
