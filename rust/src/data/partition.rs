//! Federated partitioning: iid and label-sharded non-iid splits.
//!
//! Matches the paper's setup (Sec. 4 "Implementation Details"): under iid
//! each worker draws from all labels; under non-iid each worker holds data
//! from only `labels_per_worker` of the classes (e.g. 3 of 10).

use super::synth::{Dataset, Task};
use crate::util::rng::Rng;

/// Partitioning scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Iid,
    /// Each worker sees at most this many distinct labels.
    NonIid { labels_per_worker: usize },
}

/// Result: per-worker index lists into the training split + FedAvg weights.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Vec<usize>>,
    /// omega_k = n_k / N (paper Eq. 1).
    pub weights: Vec<f32>,
}

/// Split `ds`'s training set across `k` workers.
pub fn partition(ds: &Dataset, k: usize, scheme: Scheme, seed: u64) -> Partition {
    assert!(k > 0);
    let n = ds.train_len();
    assert!(n >= k, "need at least one sample per worker");
    let mut rng = Rng::new(seed);
    let shards: Vec<Vec<usize>> = match scheme {
        Scheme::Iid => {
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            chunk_round_robin(&idx, k)
        }
        Scheme::NonIid { labels_per_worker } => {
            if ds.spec.task != Task::Classification {
                // Regression/LM: sort by a latent proxy (first feature) so
                // shards are still heterogeneous.
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by(|&a, &b| {
                    ds.train_x[a * ds.dim()]
                        .partial_cmp(&ds.train_x[b * ds.dim()])
                        .unwrap()
                });
                chunk_contiguous(&idx, k)
            } else {
                label_shard(ds, k, labels_per_worker, &mut rng)
            }
        }
    };
    let total: usize = shards.iter().map(|s| s.len()).sum();
    let weights = shards.iter().map(|s| s.len() as f32 / total as f32).collect();
    Partition { shards, weights }
}

fn chunk_round_robin(idx: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut shards = vec![Vec::new(); k];
    for (i, &v) in idx.iter().enumerate() {
        shards[i % k].push(v);
    }
    shards
}

fn chunk_contiguous(idx: &[usize], k: usize) -> Vec<Vec<usize>> {
    let per = idx.len() / k;
    let mut shards = Vec::with_capacity(k);
    for w in 0..k {
        let lo = w * per;
        let hi = if w + 1 == k { idx.len() } else { lo + per };
        shards.push(idx[lo..hi].to_vec());
    }
    shards
}

/// The paper's label-sharding: group samples by label, split each label's
/// pool into contiguous shards, deal `labels_per_worker` shards to each
/// worker.
fn label_shard(
    ds: &Dataset,
    k: usize,
    labels_per_worker: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let classes = ds.spec.classes;
    let lpw = labels_per_worker.clamp(1, classes);
    let mut by_label: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for i in 0..ds.train_len() {
        by_label[ds.train_y[i] as usize].push(i);
    }
    // Total shards = k * lpw, spread across labels proportionally.
    let total_shards = k * lpw;
    let mut label_shards: Vec<Vec<usize>> = Vec::with_capacity(total_shards);
    for (label, pool) in by_label.iter().enumerate() {
        let n_shards = (total_shards * pool.len() + ds.train_len() - 1) / ds.train_len();
        let n_shards = n_shards.max(1);
        let per = (pool.len() / n_shards).max(1);
        for s in 0..n_shards {
            let lo = s * per;
            let hi = if s + 1 == n_shards { pool.len() } else { (lo + per).min(pool.len()) };
            if lo < hi {
                label_shards.push(pool[lo..hi].to_vec());
            }
        }
        let _ = label;
    }
    rng.shuffle(&mut label_shards);
    // Deal shards to workers round-robin; every worker gets >= 1 shard.
    let mut shards = vec![Vec::new(); k];
    for (i, s) in label_shards.into_iter().enumerate() {
        shards[i % k].extend(s);
    }
    // Guarantee non-empty shards (move from the largest).
    for w in 0..k {
        if shards[w].is_empty() {
            let donor = (0..k).max_by_key(|&i| shards[i].len()).unwrap();
            let v = shards[donor].pop().unwrap();
            shards[w].push(v);
        }
    }
    shards
}

impl Partition {
    /// Number of distinct labels in a worker's shard.
    pub fn labels_of(&self, ds: &Dataset, worker: usize) -> usize {
        let mut seen = vec![false; ds.spec.classes];
        for &i in &self.shards[worker] {
            seen[ds.train_y[i] as usize] = true;
        }
        seen.iter().filter(|&&s| s).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn ds() -> Dataset {
        Dataset::generate(&SynthSpec::mnist(600, 50))
    }

    fn assert_disjoint_cover(p: &Partition, n: usize) {
        let mut seen = vec![false; n];
        for shard in &p.shards {
            for &i in shard {
                assert!(!seen[i], "index {i} in two shards");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "indices not covered");
    }

    #[test]
    fn iid_disjoint_cover_and_weights() {
        let d = ds();
        let p = partition(&d, 10, Scheme::Iid, 0);
        assert_disjoint_cover(&p, 600);
        let sum: f32 = p.weights.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(p.shards.iter().all(|s| s.len() == 60));
    }

    #[test]
    fn iid_workers_see_all_labels() {
        let d = ds();
        let p = partition(&d, 10, Scheme::Iid, 1);
        for w in 0..10 {
            assert!(p.labels_of(&d, w) >= 8, "w={w} labels={}", p.labels_of(&d, w));
        }
    }

    #[test]
    fn noniid_limits_labels() {
        let d = ds();
        let p = partition(&d, 10, Scheme::NonIid { labels_per_worker: 3 }, 2);
        assert_disjoint_cover(&p, 600);
        for w in 0..10 {
            let l = p.labels_of(&d, w);
            assert!(l <= 4, "worker {w} has {l} labels"); // shard dealing slack
            assert!(l >= 1);
        }
        // Non-iid must be *more* skewed than iid on average.
        let avg: f64 =
            (0..10).map(|w| p.labels_of(&d, w) as f64).sum::<f64>() / 10.0;
        assert!(avg < 5.0, "avg labels {avg}");
    }

    #[test]
    fn no_empty_shards() {
        let d = ds();
        for k in [2, 7, 10, 50] {
            for scheme in [Scheme::Iid, Scheme::NonIid { labels_per_worker: 2 }] {
                let p = partition(&d, k, scheme, 3);
                assert!(p.shards.iter().all(|s| !s.is_empty()), "k={k}");
                assert_eq!(p.shards.len(), k);
            }
        }
    }

    #[test]
    fn regression_noniid_heterogeneous() {
        let d = Dataset::generate(&SynthSpec::celeba(200, 20));
        let p = partition(&d, 4, Scheme::NonIid { labels_per_worker: 3 }, 5);
        assert_disjoint_cover(&p, 200);
    }

    #[test]
    fn deterministic_partition() {
        let d = ds();
        let a = partition(&d, 10, Scheme::NonIid { labels_per_worker: 3 }, 7);
        let b = partition(&d, 10, Scheme::NonIid { labels_per_worker: 3 }, 7);
        assert_eq!(a.shards, b.shards);
    }
}
