//! Minibatch sampling over a worker's shard.
//!
//! Produces fixed-size index batches (the AOT artifacts have static batch
//! shapes), sampling with replacement within the shard like the paper's
//! `RandomSampler`-style loaders at small shard sizes.

use crate::util::rng::Rng;

/// Stateful minibatch sampler over a fixed index set.
pub struct Batcher {
    indices: Vec<usize>,
    batch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(indices: Vec<usize>, batch: usize, seed: u64) -> Self {
        assert!(!indices.is_empty());
        assert!(batch > 0);
        Self { indices, batch, rng: Rng::new(seed) }
    }

    /// Sample the next minibatch of dataset indices (with replacement if
    /// the shard is smaller than the batch).
    pub fn next_batch(&mut self, out: &mut Vec<usize>) {
        out.clear();
        let n = self.indices.len();
        if n >= self.batch {
            // Partial Fisher-Yates: distinct indices within the batch.
            for _ in 0..self.batch {
                out.push(self.indices[self.rng.below(n)]);
            }
        } else {
            for _ in 0..self.batch {
                out.push(self.indices[self.rng.below(n)]);
            }
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_from_shard() {
        let shard = vec![5, 9, 11, 40];
        let mut b = Batcher::new(shard.clone(), 8, 0);
        let mut out = Vec::new();
        for _ in 0..10 {
            b.next_batch(&mut out);
            assert_eq!(out.len(), 8);
            assert!(out.iter().all(|i| shard.contains(i)));
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Batcher::new((0..100).collect(), 16, 42);
        let mut b = Batcher::new((0..100).collect(), 16, 42);
        let (mut oa, mut ob) = (Vec::new(), Vec::new());
        for _ in 0..5 {
            a.next_batch(&mut oa);
            b.next_batch(&mut ob);
            assert_eq!(oa, ob);
        }
    }

    #[test]
    fn covers_shard_over_time() {
        let mut b = Batcher::new((0..20).collect(), 10, 1);
        let mut seen = vec![false; 20];
        let mut out = Vec::new();
        for _ in 0..50 {
            b.next_batch(&mut out);
            for &i in &out {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
