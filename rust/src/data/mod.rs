//! Synthetic datasets + federated partitioning.
//!
//! The build host has no access to MNIST/FMNIST/CIFAR/CelebA downloads, so
//! every dataset the paper trains on is replaced by a deterministic
//! synthetic analogue with the same interface properties (multi-class
//! structure, label-shardable, stochastic minibatches); see DESIGN.md
//! "Substitutions" for why this preserves the paper's claims.

pub mod batcher;
pub mod corpus;
pub mod partition;
pub mod synth;

pub use batcher::Batcher;
pub use corpus::MarkovCorpus;
pub use partition::{partition, Partition, Scheme};
pub use synth::{Dataset, SynthSpec, Task};
