//! Deterministic synthetic dataset generators (MNIST/FMNIST/CIFAR/CelebA
//! stand-ins).
//!
//! Classification datasets are class-conditional Gaussian mixtures: each
//! class owns a few smooth random "templates" in input space; a sample is a
//! random template plus structured low-frequency noise plus white noise.
//! Dataset difficulty is controlled by template separation and noise scale
//! (synth_cifar is configured harder than synth_mnist, mirroring the
//! paper's accuracy ordering). The regression dataset (synth_celeba)
//! generates targets as a fixed nonlinear function of latent factors —
//! a landmark-regression analogue.

use crate::util::rng::Rng;

/// Learning task of a dataset (decides label encoding + eval metric).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Classification,
    Regression,
    LanguageModel,
}

/// Generation parameters for a synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub dim: usize,
    pub classes: usize,
    pub train_n: usize,
    pub test_n: usize,
    /// Templates per class (intra-class multi-modality).
    pub modes: usize,
    /// Template separation scale (higher = easier).
    pub sep: f32,
    /// White-noise std.
    pub noise: f32,
    pub task: Task,
    pub seed: u64,
}

impl SynthSpec {
    /// 784-d, 10-class, well-separated (MNIST-difficulty analogue).
    pub fn mnist(train_n: usize, test_n: usize) -> Self {
        Self {
            name: "synth_mnist".into(),
            dim: 784,
            classes: 10,
            train_n,
            test_n,
            modes: 2,
            sep: 2.2,
            noise: 0.8,
            task: Task::Classification,
            seed: 101,
        }
    }

    /// 784-d, 10-class, moderately separated (FMNIST analogue).
    pub fn fmnist(train_n: usize, test_n: usize) -> Self {
        Self {
            name: "synth_fmnist".into(),
            dim: 784,
            classes: 10,
            train_n,
            test_n,
            modes: 3,
            sep: 1.6,
            noise: 1.0,
            task: Task::Classification,
            seed: 202,
        }
    }

    /// 3072-d, 10-class, hard (CIFAR-10 analogue).
    pub fn cifar(train_n: usize, test_n: usize) -> Self {
        Self {
            name: "synth_cifar".into(),
            dim: 3072,
            classes: 10,
            train_n,
            test_n,
            modes: 4,
            sep: 1.0,
            noise: 1.2,
            task: Task::Classification,
            seed: 303,
        }
    }

    /// 3072-d regression with 10 outputs (CelebA landmark analogue).
    pub fn celeba(train_n: usize, test_n: usize) -> Self {
        Self {
            name: "synth_celeba".into(),
            dim: 3072,
            classes: 10, // = number of regression outputs
            train_n,
            test_n,
            modes: 1,
            sep: 1.0,
            noise: 0.5,
            task: Task::Regression,
            seed: 404,
        }
    }
}

/// Materialized dataset: row-major features plus labels/targets.
pub struct Dataset {
    pub spec: SynthSpec,
    pub train_x: Vec<f32>, // train_n x dim
    pub train_y: Vec<i32>, // classification labels (empty for regression)
    pub train_t: Vec<f32>, // regression targets train_n x classes (empty for cls)
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
    pub test_t: Vec<f32>,
}

/// Smooth low-frequency template: random walk smoothed over the input dim,
/// giving image-like spatial correlation instead of white noise.
fn smooth_template(rng: &mut Rng, dim: usize, scale: f32) -> Vec<f32> {
    let mut v = vec![0f32; dim];
    let mut acc = 0f32;
    for x in v.iter_mut() {
        acc = 0.9 * acc + rng.normal_f32(0.0, 1.0);
        *x = acc;
    }
    // Normalize to unit RMS then scale.
    let rms = (v.iter().map(|x| x * x).sum::<f32>() / dim as f32).sqrt();
    if rms > 0.0 {
        for x in v.iter_mut() {
            *x = *x / rms * scale;
        }
    }
    v
}

impl Dataset {
    pub fn generate(spec: &SynthSpec) -> Dataset {
        let mut rng = Rng::new(spec.seed);
        let templates: Vec<Vec<Vec<f32>>> = (0..spec.classes)
            .map(|_| {
                (0..spec.modes)
                    .map(|_| smooth_template(&mut rng, spec.dim, spec.sep))
                    .collect()
            })
            .collect();
        // Regression: a fixed random readout matrix maps latents to targets.
        let readout: Vec<f32> = (0..spec.classes * 4)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();

        let mut gen_split = |n: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(n * spec.dim);
            let mut ys = Vec::new();
            let mut ts = Vec::new();
            for i in 0..n {
                match spec.task {
                    Task::Classification => {
                        let c = i % spec.classes; // balanced
                        let m = rng.below(spec.modes);
                        let t = &templates[c][m];
                        for j in 0..spec.dim {
                            xs.push(t[j] + rng.normal_f32(0.0, spec.noise));
                        }
                        ys.push(c as i32);
                    }
                    Task::Regression => {
                        // latent z in R^4 -> x = smooth mix + noise,
                        // y = tanh-nonlinear readout of z.
                        let z: Vec<f32> =
                            (0..4).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                        let base = &templates[0][0];
                        for j in 0..spec.dim {
                            let phase = (j % 4) as usize;
                            xs.push(
                                base[j] * z[phase]
                                    + rng.normal_f32(0.0, spec.noise),
                            );
                        }
                        for o in 0..spec.classes {
                            let mut acc = 0f32;
                            for (k, zk) in z.iter().enumerate() {
                                acc += readout[o * 4 + k] * zk;
                            }
                            ts.push(acc.tanh());
                        }
                        ys.push(0);
                    }
                    Task::LanguageModel => unreachable!("use MarkovCorpus"),
                }
            }
            (xs, ys, ts)
        };

        let (train_x, train_y, train_t) = gen_split(spec.train_n, &mut rng);
        let (test_x, test_y, test_t) = gen_split(spec.test_n, &mut rng);
        Dataset {
            spec: spec.clone(),
            train_x,
            train_y,
            train_t,
            test_x,
            test_y,
            test_t,
        }
    }

    pub fn dim(&self) -> usize {
        self.spec.dim
    }

    pub fn train_len(&self) -> usize {
        self.spec.train_n
    }

    pub fn test_len(&self) -> usize {
        self.spec.test_n
    }

    /// Copy feature rows `idx` into `out_x` and labels into `out_y`
    /// (classification) or targets into `out_t` (regression).
    pub fn gather_train(
        &self,
        idx: &[usize],
        out_x: &mut Vec<f32>,
        out_y: &mut Vec<i32>,
        out_t: &mut Vec<f32>,
    ) {
        out_x.clear();
        out_y.clear();
        out_t.clear();
        let d = self.spec.dim;
        let o = self.spec.classes;
        for &i in idx {
            out_x.extend_from_slice(&self.train_x[i * d..(i + 1) * d]);
            if self.spec.task == Task::Regression {
                out_t.extend_from_slice(&self.train_t[i * o..(i + 1) * o]);
            } else {
                out_y.push(self.train_y[i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = SynthSpec::mnist(64, 32);
        let a = Dataset::generate(&spec);
        let b = Dataset::generate(&spec);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.test_x, b.test_x);
    }

    #[test]
    fn balanced_labels() {
        let d = Dataset::generate(&SynthSpec::mnist(100, 50));
        let mut counts = [0usize; 10];
        for &y in &d.train_y {
            counts[y as usize] += 1;
        }
        assert_eq!(counts, [10; 10]);
    }

    #[test]
    fn shapes_consistent() {
        let d = Dataset::generate(&SynthSpec::cifar(40, 20));
        assert_eq!(d.train_x.len(), 40 * 3072);
        assert_eq!(d.train_y.len(), 40);
        assert!(d.train_t.is_empty());
        assert_eq!(d.test_x.len(), 20 * 3072);
    }

    #[test]
    fn regression_targets_bounded() {
        let d = Dataset::generate(&SynthSpec::celeba(30, 10));
        assert_eq!(d.train_t.len(), 30 * 10);
        assert!(d.train_t.iter().all(|t| t.abs() <= 1.0));
        assert!(d.train_y.iter().all(|&y| y == 0));
    }

    #[test]
    fn classes_are_separated() {
        // Same-class samples must be closer (on average) than cross-class.
        let d = Dataset::generate(&SynthSpec::mnist(200, 10));
        let dim = d.dim();
        let dist = |i: usize, j: usize| -> f32 {
            let a = &d.train_x[i * dim..(i + 1) * dim];
            let b = &d.train_x[j * dim..(j + 1) * dim];
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut same = (0f64, 0usize);
        let mut diff = (0f64, 0usize);
        for i in 0..60 {
            for j in (i + 1)..60 {
                if d.train_y[i] == d.train_y[j] {
                    same = (same.0 + dist(i, j) as f64, same.1 + 1);
                } else {
                    diff = (diff.0 + dist(i, j) as f64, diff.1 + 1);
                }
            }
        }
        let (ms, md) = (same.0 / same.1 as f64, diff.0 / diff.1 as f64);
        assert!(ms < md, "same-class {ms} !< cross-class {md}");
    }

    #[test]
    fn gather_train_layout() {
        let d = Dataset::generate(&SynthSpec::mnist(20, 5));
        let (mut x, mut y, mut t) = (Vec::new(), Vec::new(), Vec::new());
        d.gather_train(&[3, 7], &mut x, &mut y, &mut t);
        assert_eq!(x.len(), 2 * 784);
        assert_eq!(y, vec![d.train_y[3], d.train_y[7]]);
        assert_eq!(&x[..784], &d.train_x[3 * 784..4 * 784]);
    }
}
