//! Gradient-space analysis (paper Sec. 2, Alg. 2; Figs. 1-3).
//!
//! Records the accumulated gradient of every centralized training epoch,
//! tracks the N95/N99-PCA progression incrementally, extracts principal
//! gradient directions (PGDs), and produces the per-layer cosine-similarity
//! heatmaps that motivate LBGM's two hypotheses (H1: the gradient-space is
//! low-rank; H2: PGDs are approximated by actual gradients).

pub mod gradient_space;
pub mod recorder;
pub mod similarity;

pub use gradient_space::{centralized_analysis, CentralizedReport};
pub use recorder::GradientRecorder;
pub use similarity::{pairwise_heatmap, pgd_overlap_heatmap, Heatmap};
