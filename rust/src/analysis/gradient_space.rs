//! Centralized-training gradient-space study (paper Alg. 2 / Fig. 1).
//!
//! Runs plain centralized SGD (K=1 "federation", tau = batches-per-epoch)
//! through any [`LocalTrainer`], records the accumulated epoch gradients,
//! and tracks N95/N99-PCA after every epoch together with the test metric —
//! exactly the two rows of Fig. 1.

use anyhow::Result;

use crate::coordinator::trainer::LocalTrainer;
use crate::linalg::gram_pca::GramPca;
use crate::runtime::Segment;

use super::recorder::GradientRecorder;

/// Per-epoch record of the Fig. 1 quantities.
#[derive(Clone, Debug)]
pub struct EpochPca {
    pub epoch: usize,
    pub n95: usize,
    pub n99: usize,
    pub test_loss: f64,
    pub test_metric: f64,
}

/// Full output of the centralized analysis.
pub struct CentralizedReport {
    pub per_epoch: Vec<EpochPca>,
    pub recorder: GradientRecorder,
}

impl CentralizedReport {
    /// Max N99 over the run, as a fraction of epochs (H1's headline: the
    /// paper observes this "often as low as 10%").
    pub fn n99_fraction(&self) -> f64 {
        let epochs = self.per_epoch.len().max(1);
        let n99 = self.per_epoch.last().map(|e| e.n99).unwrap_or(0);
        n99 as f64 / epochs as f64
    }
}

/// Train centrally for `epochs` epochs of `steps_per_epoch` minibatch steps
/// and perform the Alg. 2 analysis.
pub fn centralized_analysis(
    trainer: &mut dyn LocalTrainer,
    theta0: Vec<f32>,
    segments: Vec<Segment>,
    epochs: usize,
    steps_per_epoch: usize,
    eta: f32,
) -> Result<CentralizedReport> {
    let dim = trainer.dim();
    anyhow::ensure!(trainer.workers() == 1, "centralized analysis uses 1 worker");
    let mut theta = theta0;
    let mut recorder = GradientRecorder::new(dim, segments);
    let mut pca = GramPca::new(dim);
    let mut per_epoch = Vec::with_capacity(epochs);

    for epoch in 0..epochs {
        // One "epoch" = steps_per_epoch local SGD steps; the accumulated
        // gradient is what Alg. 2 stores for PCA.
        let (_, acc) = trainer.local_round(0, &theta, steps_per_epoch, eta)?;
        // Apply the accumulated update (equivalent to the local steps).
        // local_round already simulated the trajectory; the global theta
        // follows it: theta <- theta - eta * acc is NOT identical to the
        // local endpoint under curvature, so we re-walk via a single round
        // of the same trainer state. For analysis purposes the paper's
        // Alg. 2 uses the epoch-end parameters; we approximate with the
        // accumulated-gradient step, which matches for tau-step SGD on the
        // recorded trajectory up to O(eta^2) and is exact for tau=1.
        crate::linalg::vec_ops::axpy(-eta, &acc, &mut theta);
        // §Perf: the PCA accumulator copies `acc` into its flat matrix, so
        // the recorder can take ownership without an extra clone.
        pca.push(&acc);
        recorder.record(acc);
        let (test_loss, test_metric) = trainer.eval(&theta)?;
        let (n95, n99) = pca.n_pca();
        per_epoch.push(EpochPca { epoch, n95, n99, test_loss, test_metric });
    }

    Ok(CentralizedReport { per_epoch, recorder })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::MockTrainer;

    fn segments(dim: usize) -> Vec<Segment> {
        vec![
            Segment { name: "a".into(), offset: 0, size: dim / 2, shape: vec![dim / 2] },
            Segment {
                name: "b".into(),
                offset: dim / 2,
                size: dim - dim / 2,
                shape: vec![dim - dim / 2],
            },
        ]
    }

    #[test]
    fn quadratic_gradspace_is_low_rank() {
        // Noise-free quadratic: gradients lie on a line toward the optimum
        // => N99 stays tiny relative to epochs (H1 in its sharpest form).
        let dim = 64;
        let mut t = MockTrainer::new(dim, 1, 0.0, 0.0, 1);
        let report = centralized_analysis(
            &mut t,
            vec![0.0; dim],
            segments(dim),
            20,
            1,
            0.05,
        )
        .unwrap();
        let last = report.per_epoch.last().unwrap();
        assert!(last.n99 <= 2, "n99={}", last.n99);
        assert!(report.n99_fraction() < 0.2);
        assert_eq!(report.recorder.epochs(), 20);
    }

    #[test]
    fn noisy_gradspace_has_higher_rank() {
        let dim = 64;
        let mut clean = MockTrainer::new(dim, 1, 0.0, 0.0, 2);
        let mut noisy = MockTrainer::new(dim, 1, 0.0, 0.5, 2);
        let rc = centralized_analysis(&mut clean, vec![0.0; dim], segments(dim), 15, 1, 0.05)
            .unwrap();
        let rn = centralized_analysis(&mut noisy, vec![0.0; dim], segments(dim), 15, 1, 0.05)
            .unwrap();
        assert!(
            rn.per_epoch.last().unwrap().n99 > rc.per_epoch.last().unwrap().n99,
            "noise should raise rank"
        );
    }

    #[test]
    fn loss_decreases_during_analysis() {
        let dim = 32;
        let mut t = MockTrainer::new(dim, 1, 0.0, 0.01, 3);
        let r = centralized_analysis(&mut t, vec![0.0; dim], segments(dim), 25, 2, 0.05)
            .unwrap();
        let first = r.per_epoch.first().unwrap().test_loss;
        let last = r.per_epoch.last().unwrap().test_loss;
        assert!(last < 0.5 * first, "{first} -> {last}");
    }
}
