//! Cosine-similarity heatmaps (paper Figs. 2 and 3, Alg. 2 lines 20-34).

use crate::linalg::gram_pca::GramPca;
use crate::linalg::vec_ops::cosine;

/// Dense row-major heatmap with axis labels.
#[derive(Clone, Debug)]
pub struct Heatmap {
    pub rows: usize,
    pub cols: usize,
    pub values: Vec<f64>,
    pub title: String,
}

impl Heatmap {
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.values[i * self.cols + j]
    }

    /// Compact ASCII rendering (for terminal reports / EXPERIMENTS.md).
    pub fn ascii(&self) -> String {
        let ramp = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
        let mut out = format!("{} ({}x{})\n", self.title, self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.get(i, j).abs().clamp(0.0, 1.0);
                let idx = ((v * 9.0).round() as usize).min(9);
                out.push(ramp[idx]);
            }
            out.push('\n');
        }
        out
    }
}

/// Fig. 3: pairwise |cosine| similarity among epoch gradients of one layer.
pub fn pairwise_heatmap(grads: &[Vec<f32>], title: &str) -> Heatmap {
    let n = grads.len();
    let mut values = vec![0f64; n * n];
    for i in 0..n {
        values[i * n + i] = 1.0;
        for j in (i + 1)..n {
            let c = cosine(&grads[i], &grads[j]);
            values[i * n + j] = c;
            values[j * n + i] = c;
        }
    }
    Heatmap { rows: n, cols: n, values, title: title.to_string() }
}

/// Fig. 2: cosine similarity between actual epoch gradients (rows) and the
/// principal gradient directions explaining `fraction` variance (cols).
pub fn pgd_overlap_heatmap(grads: &[Vec<f32>], fraction: f64, title: &str) -> Heatmap {
    assert!(!grads.is_empty());
    let mut pca = GramPca::new(grads[0].len());
    for g in grads {
        pca.push(g);
    }
    let pgds = pca.principal_directions(fraction);
    let (n, k) = (grads.len(), pgds.len());
    let mut values = vec![0f64; n * k];
    for i in 0..n {
        for j in 0..k {
            values[i * k + j] = cosine(&grads[i], &pgds[j]);
        }
    }
    Heatmap { rows: n, cols: k, values, title: title.to_string() }
}

/// Summary statistic used in EXPERIMENTS.md for Fig. 2: for every epoch
/// gradient, the max |cosine| against any PGD ("each gradient overlaps
/// strongly with one or more PGDs").
pub fn max_overlap_per_gradient(h: &Heatmap) -> Vec<f64> {
    (0..h.rows)
        .map(|i| {
            (0..h.cols)
                .map(|j| h.get(i, j).abs())
                .fold(0.0f64, f64::max)
        })
        .collect()
}

/// Summary statistic for Fig. 3: mean |cosine| of consecutive gradients.
pub fn mean_consecutive_similarity(h: &Heatmap) -> f64 {
    if h.rows < 2 {
        return 1.0;
    }
    (0..h.rows - 1)
        .map(|i| h.get(i, i + 1).abs())
        .sum::<f64>()
        / (h.rows - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn family(n: usize, drift: f32, seed: u64) -> Vec<Vec<f32>> {
        // Slowly rotating family: g_{t+1} = g_t + drift * noise.
        let mut rng = Rng::new(seed);
        let mut g: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![g.clone()];
        for _ in 1..n {
            for x in g.iter_mut() {
                *x += drift * rng.normal_f32(0.0, 1.0);
            }
            out.push(g.clone());
        }
        out
    }

    #[test]
    fn pairwise_symmetric_unit_diagonal() {
        let h = pairwise_heatmap(&family(6, 0.3, 1), "t");
        for i in 0..6 {
            assert!((h.get(i, i) - 1.0).abs() < 1e-9);
            for j in 0..6 {
                assert!((h.get(i, j) - h.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn slow_drift_has_high_consecutive_similarity() {
        let slow = pairwise_heatmap(&family(10, 0.05, 2), "slow");
        let fast = pairwise_heatmap(&family(10, 2.0, 2), "fast");
        let (ms, mf) = (
            mean_consecutive_similarity(&slow),
            mean_consecutive_similarity(&fast),
        );
        assert!(ms > 0.95, "slow drift similarity {ms}");
        assert!(ms > mf, "{ms} !> {mf}");
    }

    #[test]
    fn pgd_overlap_high_for_low_rank_family() {
        // Rank-~1 family: every gradient overlaps the single PGD strongly.
        let base: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let grads: Vec<Vec<f32>> =
            (1..8).map(|s| base.iter().map(|x| x * s as f32).collect()).collect();
        let h = pgd_overlap_heatmap(&grads, 0.99, "t");
        assert_eq!(h.cols, 1);
        for m in max_overlap_per_gradient(&h) {
            assert!(m > 0.999, "overlap {m}");
        }
    }

    #[test]
    fn ascii_renders() {
        let h = pairwise_heatmap(&family(4, 0.1, 3), "demo");
        let a = h.ascii();
        assert!(a.lines().count() == 5);
        assert!(a.contains("demo"));
    }
}
