//! Epoch-gradient recorder with per-layer views.

use crate::runtime::Segment;

/// Stores the accumulated gradient of each training epoch, exposing both
/// the full flat vectors and per-layer slices (the paper's Figs. 2-3 are
/// per-layer heatmaps, driven by the manifest's segment table).
pub struct GradientRecorder {
    dim: usize,
    pub segments: Vec<Segment>,
    grads: Vec<Vec<f32>>,
}

impl GradientRecorder {
    pub fn new(dim: usize, segments: Vec<Segment>) -> Self {
        if let Some(last) = segments.last() {
            assert_eq!(last.offset + last.size, dim, "segments must cover dim");
        }
        Self { dim, segments, grads: Vec::new() }
    }

    pub fn record(&mut self, grad: Vec<f32>) {
        assert_eq!(grad.len(), self.dim);
        self.grads.push(grad);
    }

    pub fn epochs(&self) -> usize {
        self.grads.len()
    }

    pub fn grad(&self, epoch: usize) -> &[f32] {
        &self.grads[epoch]
    }

    /// Layer `l`'s slice of epoch `e`'s gradient.
    pub fn layer_slice(&self, epoch: usize, layer: usize) -> &[f32] {
        let s = &self.segments[layer];
        &self.grads[epoch][s.offset..s.offset + s.size]
    }

    /// All epochs of one layer, copied into contiguous rows (for PCA).
    pub fn layer_matrix(&self, layer: usize) -> Vec<Vec<f32>> {
        (0..self.epochs())
            .map(|e| self.layer_slice(e, layer).to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(name: &str, offset: usize, size: usize) -> Segment {
        Segment { name: name.into(), offset, size, shape: vec![size] }
    }

    #[test]
    fn layer_views() {
        let mut r = GradientRecorder::new(5, vec![seg("a", 0, 2), seg("b", 2, 3)]);
        r.record(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        r.record(vec![10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(r.epochs(), 2);
        assert_eq!(r.layer_slice(0, 0), &[1.0, 2.0]);
        assert_eq!(r.layer_slice(1, 1), &[30.0, 40.0, 50.0]);
        let m = r.layer_matrix(1);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0], vec![3.0, 4.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_dim() {
        let mut r = GradientRecorder::new(3, vec![seg("a", 0, 3)]);
        r.record(vec![1.0]);
    }
}
