//! Per-round metric series for a single training run.

use crate::coordinator::accounting::TierTotals;

/// One global aggregation round's metrics.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    pub train_loss: f64,
    pub test_loss: f64,
    /// Accuracy for classification/LM, negative MSE proxy for regression.
    pub test_metric: f64,
    /// Cumulative floats transferred uplink (all workers) after this round.
    pub floats_up: u64,
    /// Cumulative uplink bits (exact, for SignSGD-style codecs).
    pub bits_up: u64,
    /// Cumulative modeled downlink floats (theta broadcasts).
    pub floats_down: u64,
    /// Cumulative modeled downlink bits.
    pub bits_down: u64,
    /// Cumulative measured wire bytes received by the server (0 for
    /// in-memory transports; exact framed bytes for the net deployment).
    pub wire_up_bytes: u64,
    /// Cumulative measured wire bytes sent by the server.
    pub wire_down_bytes: u64,
    /// Cumulative raw-equivalent uplink bytes: what the same frames
    /// would have measured on a protocol-v3 `raw` session. Equals
    /// `wire_up_bytes` on raw sessions; the gap is the `q8`/`f16`
    /// codec saving. (JSON summary only — not a CSV column.)
    pub wire_up_raw_bytes: u64,
    /// Cumulative raw-equivalent downlink bytes (dense broadcasts).
    pub wire_down_raw_bytes: u64,
    /// Workers that sent a full gradient (vs a scalar LBC) this round.
    pub full_sends: usize,
    pub scalar_sends: usize,
    pub wall_secs: f64,
    /// Workers whose updates made this round's aggregation (equals
    /// `full_sends + scalar_sends`; less than the sampled set when faults
    /// or deadline misses removed someone).
    pub participants: usize,
    /// Sampled participants that did *not* arrive this round (dropped,
    /// late, disconnected, or corrupt — this round's count, not
    /// cumulative).
    pub faults: usize,
    /// Seconds spent in local SGD this round (wall clock; telemetry
    /// only — never compared across engines).
    pub t_train: f64,
    /// Seconds spent in LBGM uplink compression this round (0 where the
    /// engine fuses it into training).
    pub t_compress: f64,
    /// Seconds the transport spent broadcasting and collecting this
    /// round (0 for the in-process sequential engine).
    pub t_comm: f64,
    /// Seconds spent applying the aggregate this round.
    pub t_aggregate: f64,
    /// Cumulative per-device-tier communication roll-up, one row per tier
    /// in the run's [`TierMap`](crate::coordinator::accounting::TierMap)
    /// order. Empty for untiered runs. JSON-only: the frozen CSV header
    /// never carries these columns.
    pub tiers: Vec<TierTotals>,
}

/// A named training run's full history.
#[derive(Clone, Debug, Default)]
pub struct RunSeries {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunSeries {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), rounds: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    pub fn final_metric(&self) -> f64 {
        self.last().map(|r| r.test_metric).unwrap_or(f64::NAN)
    }

    pub fn total_floats(&self) -> u64 {
        self.last().map(|r| r.floats_up).unwrap_or(0)
    }

    pub fn total_bits(&self) -> u64 {
        self.last().map(|r| r.bits_up).unwrap_or(0)
    }

    /// Total modeled downlink floats (theta broadcasts) over the run.
    pub fn total_floats_down(&self) -> u64 {
        self.last().map(|r| r.floats_down).unwrap_or(0)
    }

    /// Total measured wire bytes, `(uplink, downlink)`; zero for runs on
    /// the in-memory transports.
    pub fn total_wire_bytes(&self) -> (u64, u64) {
        self.last()
            .map(|r| (r.wire_up_bytes, r.wire_down_bytes))
            .unwrap_or((0, 0))
    }

    /// Total raw-equivalent wire bytes, `(uplink, downlink)`: the bytes a
    /// `raw`-codec session would have moved for the same logical frames.
    /// The gap to [`total_wire_bytes`](Self::total_wire_bytes) is the
    /// measured quantized/delta saving; zero gap on raw and in-memory runs.
    pub fn total_wire_raw_bytes(&self) -> (u64, u64) {
        self.last()
            .map(|r| (r.wire_up_raw_bytes, r.wire_down_raw_bytes))
            .unwrap_or((0, 0))
    }

    /// Final per-tier communication roll-up. The ledger counters are
    /// cumulative, so the last round's snapshot is the run total. Empty
    /// for untiered runs.
    pub fn tier_summary(&self) -> &[TierTotals] {
        self.last().map(|r| r.tiers.as_slice()).unwrap_or(&[])
    }

    /// Total fault events over the run (absent planned participants).
    pub fn total_faults(&self) -> u64 {
        self.rounds.iter().map(|r| r.faults as u64).sum()
    }

    /// Smallest per-round participant count (0 for an empty series).
    pub fn min_participants(&self) -> usize {
        self.rounds.iter().map(|r| r.participants).min().unwrap_or(0)
    }

    /// Best (max) test metric over the run.
    pub fn best_metric(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.test_metric)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Fraction of uplink messages that were scalar LBCs.
    pub fn scalar_fraction(&self) -> f64 {
        let (s, f): (usize, usize) = self
            .rounds
            .iter()
            .fold((0, 0), |(s, f), r| (s + r.scalar_sends, f + r.full_sends));
        if s + f == 0 {
            0.0
        } else {
            s as f64 / (s + f) as f64
        }
    }

    /// Whole-run phase-timing totals
    /// `(t_train, t_compress, t_comm, t_aggregate)` in seconds.
    pub fn total_phase_secs(&self) -> (f64, f64, f64, f64) {
        self.rounds.iter().fold((0.0, 0.0, 0.0, 0.0), |acc, r| {
            (
                acc.0 + r.t_train,
                acc.1 + r.t_compress,
                acc.2 + r.t_comm,
                acc.3 + r.t_aggregate,
            )
        })
    }

    /// Communication saving vs a baseline's total floats (paper's "% savings").
    pub fn savings_vs(&self, baseline_floats: u64) -> f64 {
        if baseline_floats == 0 {
            return 0.0;
        }
        1.0 - self.total_floats() as f64 / baseline_floats as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, metric: f64, floats: u64, s: usize, f: usize) -> RoundRecord {
        RoundRecord {
            round,
            test_metric: metric,
            floats_up: floats,
            scalar_sends: s,
            full_sends: f,
            ..Default::default()
        }
    }

    #[test]
    fn summaries() {
        let mut s = RunSeries::new("x");
        s.push(rec(0, 0.1, 100, 0, 10));
        s.push(rec(1, 0.5, 110, 9, 1));
        s.push(rec(2, 0.4, 120, 10, 0));
        assert_eq!(s.final_metric(), 0.4);
        assert_eq!(s.best_metric(), 0.5);
        assert_eq!(s.total_floats(), 120);
        assert!((s.scalar_fraction() - 19.0 / 30.0).abs() < 1e-12);
        assert!((s.savings_vs(240) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn participation_and_fault_summaries() {
        let mut s = RunSeries::new("x");
        s.push(RoundRecord { round: 0, participants: 4, faults: 0, ..Default::default() });
        s.push(RoundRecord { round: 1, participants: 3, faults: 1, ..Default::default() });
        s.push(RoundRecord { round: 2, participants: 2, faults: 2, ..Default::default() });
        assert_eq!(s.total_faults(), 3);
        assert_eq!(s.min_participants(), 2);
        assert_eq!(RunSeries::new("e").min_participants(), 0);
        assert_eq!(RunSeries::new("e").total_faults(), 0);
    }

    #[test]
    fn empty_series() {
        let s = RunSeries::new("e");
        assert!(s.final_metric().is_nan());
        assert_eq!(s.total_floats(), 0);
        assert_eq!(s.scalar_fraction(), 0.0);
    }
}
