//! Experiment metrics: per-round series, summaries, and CSV/JSON writers.
//!
//! Every figure harness records its curves here; `make figures` dumps them
//! under `results/` so EXPERIMENTS.md can cite exact numbers.

pub mod series;
pub mod writer;

pub use series::{RoundRecord, RunSeries};
pub use writer::{write_csv, write_json};
