//! CSV/JSON dumps of run series for `results/`.

use std::fs;
use std::path::Path;

use super::series::RunSeries;
use crate::util::json::{arr, num, obj, s, Json};

/// Write one CSV with all runs stacked (run,round,... columns).
pub fn write_csv(path: &Path, runs: &[RunSeries]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    // Phase-timing columns are appended after the PR-5 columns so the
    // committed golden traces extend instead of breaking.
    let mut out = String::from(
        "run,round,train_loss,test_loss,test_metric,floats_up,bits_up,floats_down,bits_down,wire_up_bytes,wire_down_bytes,full_sends,scalar_sends,wall_secs,participants,faults,t_train,t_compress,t_comm,t_aggregate\n",
    );
    for run in runs {
        for r in &run.rounds {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{:.4},{},{},{:.4},{:.4},{:.4},{:.4}\n",
                run.name,
                r.round,
                r.train_loss,
                r.test_loss,
                r.test_metric,
                r.floats_up,
                r.bits_up,
                r.floats_down,
                r.bits_down,
                r.wire_up_bytes,
                r.wire_down_bytes,
                r.full_sends,
                r.scalar_sends,
                r.wall_secs,
                r.participants,
                r.faults,
                r.t_train,
                r.t_compress,
                r.t_comm,
                r.t_aggregate
            ));
        }
    }
    fs::write(path, out)?;
    Ok(())
}

/// Write a JSON summary (finals + savings) for EXPERIMENTS.md extraction.
pub fn write_json(path: &Path, runs: &[RunSeries]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let items = runs.iter().map(|r| {
        let (t_train, t_compress, t_comm, t_aggregate) = r.total_phase_secs();
        let mut fields = vec![
            ("name", s(&r.name)),
            ("rounds", num(r.rounds.len() as f64)),
            ("final_metric", num(r.final_metric())),
            ("best_metric", num(r.best_metric())),
            ("total_floats", num(r.total_floats() as f64)),
            ("total_bits", num(r.total_bits() as f64)),
            ("total_floats_down", num(r.total_floats_down() as f64)),
            ("wire_up_bytes", num(r.total_wire_bytes().0 as f64)),
            ("wire_down_bytes", num(r.total_wire_bytes().1 as f64)),
            ("wire_up_raw_bytes", num(r.total_wire_raw_bytes().0 as f64)),
            ("wire_down_raw_bytes", num(r.total_wire_raw_bytes().1 as f64)),
            ("scalar_fraction", num(r.scalar_fraction())),
            ("total_faults", num(r.total_faults() as f64)),
            ("min_participants", num(r.min_participants() as f64)),
            ("t_train", num(t_train)),
            ("t_compress", num(t_compress)),
            ("t_comm", num(t_comm)),
            ("t_aggregate", num(t_aggregate)),
        ];
        // Per-device-tier roll-up (heterogeneous fleets). JSON-only: the
        // frozen CSV header never grows these columns.
        let tiers = r.tier_summary();
        if !tiers.is_empty() {
            fields.push((
                "tiers",
                arr(tiers.iter().map(|t| {
                    obj(vec![
                        ("name", s(&t.name)),
                        ("workers", num(t.workers as f64)),
                        ("floats_up", num(t.floats_up as f64)),
                        ("bits_up", num(t.bits_up as f64)),
                        ("floats_down", num(t.floats_down as f64)),
                        ("bits_down", num(t.bits_down as f64)),
                        ("wire_up_bytes", num(t.wire_up_bytes as f64)),
                        ("wire_down_bytes", num(t.wire_down_bytes as f64)),
                        ("wire_up_raw_bytes", num(t.wire_up_raw_bytes as f64)),
                        ("wire_down_raw_bytes", num(t.wire_down_raw_bytes as f64)),
                        ("savings_up_bytes", num(t.savings_up_bytes as f64)),
                        ("savings_down_bytes", num(t.savings_down_bytes as f64)),
                        ("faults", num(t.faults as f64)),
                        ("rejoins", num(t.rejoins as f64)),
                    ])
                })),
            ));
        }
        obj(fields)
    });
    fs::write(path, Json::to_string(&arr(items)))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::series::RoundRecord;

    #[test]
    fn csv_and_json_roundtrip() {
        let dir = std::env::temp_dir().join("fedrecycle_metrics_test");
        let mut run = RunSeries::new("demo");
        run.push(RoundRecord { round: 0, test_metric: 0.5, floats_up: 10, ..Default::default() });
        write_csv(&dir.join("a.csv"), &[run.clone()]).unwrap();
        write_json(&dir.join("a.json"), &[run]).unwrap();
        let csv = std::fs::read_to_string(dir.join("a.csv")).unwrap();
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("demo,0"));
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("participants,faults,t_train,t_compress,t_comm,t_aggregate"));
        assert!(csv.lines().nth(1).unwrap().ends_with(",0.0000,0.0000,0.0000,0.0000"));
        let j = Json::parse(&std::fs::read_to_string(dir.join("a.json")).unwrap()).unwrap();
        assert_eq!(j.as_arr().unwrap()[0].req_str("name").unwrap(), "demo");
        assert_eq!(j.as_arr().unwrap()[0].req_f64("total_faults").unwrap(), 0.0);
        assert_eq!(j.as_arr().unwrap()[0].req_f64("t_aggregate").unwrap(), 0.0);
        // Untiered runs carry no "tiers" key at all.
        assert!(j.as_arr().unwrap()[0].get("tiers").is_none());
    }

    #[test]
    fn json_carries_tier_rollups_when_present() {
        use crate::coordinator::accounting::TierTotals;
        let dir = std::env::temp_dir().join("fedrecycle_metrics_tier_test");
        let mut run = RunSeries::new("tiered");
        run.push(RoundRecord {
            round: 0,
            tiers: vec![
                TierTotals {
                    name: "fiber".into(),
                    workers: 2,
                    wire_up_bytes: 10,
                    wire_up_raw_bytes: 14,
                    savings_up_bytes: 4,
                    ..Default::default()
                },
                TierTotals { name: "cellular".into(), workers: 3, ..Default::default() },
            ],
            ..Default::default()
        });
        write_json(&dir.join("t.json"), &[run.clone()]).unwrap();
        let j = Json::parse(&std::fs::read_to_string(dir.join("t.json")).unwrap()).unwrap();
        let tiers = j.as_arr().unwrap()[0].get("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].req_str("name").unwrap(), "fiber");
        assert_eq!(tiers[0].req_f64("workers").unwrap(), 2.0);
        assert_eq!(tiers[0].req_f64("savings_up_bytes").unwrap(), 4.0);
        assert_eq!(tiers[1].req_str("name").unwrap(), "cellular");
        // The CSV header is frozen: tier rows never grow CSV columns.
        write_csv(&dir.join("t.csv"), &[run]).unwrap();
        let csv = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("participants,faults,t_train,t_compress,t_comm,t_aggregate"));
    }
}
