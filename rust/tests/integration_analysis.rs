//! Integration: the Sec. 2 gradient-space analysis on a real PJRT-trained
//! model — H1 (low-rank) and H2 (gradual rotation) must hold on the actual
//! artifacts, not just the analytic mock.

use fedrecycle::analysis::gradient_space::centralized_analysis;
use fedrecycle::analysis::similarity::{
    max_overlap_per_gradient, mean_consecutive_similarity, pairwise_heatmap,
    pgd_overlap_heatmap,
};
use fedrecycle::config::ExperimentConfig;
use fedrecycle::coordinator::PjrtTrainer;
use fedrecycle::data::{partition, Dataset, Scheme, SynthSpec};
use fedrecycle::runtime::{Manifest, Runtime};

fn centralized(
    rt: &Runtime,
    m: &Manifest,
    variant: &str,
) -> fedrecycle::analysis::gradient_space::CentralizedReport {
    let meta = m.variant(variant).unwrap();
    let ds = Dataset::generate(&SynthSpec::mnist(512, 96));
    let part = partition(&ds, 1, Scheme::Iid, 1);
    let mut trainer = PjrtTrainer::image(rt, meta, ds, part, 3).unwrap();
    centralized_analysis(
        &mut trainer,
        meta.load_init().unwrap(),
        meta.segments.clone(),
        12, // epochs
        4,  // steps per epoch
        0.05,
    )
    .unwrap()
}

#[test]
fn h1_gradient_space_is_low_rank_on_real_model() {
    let Some(m) = Manifest::load(&Manifest::default_dir()).ok() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let report = centralized(&rt, &m, "fcn_mnist");
    let last = report.per_epoch.last().unwrap();
    // 12 epoch gradients; H1 says N99 is well below that.
    assert!(last.n99 < 12, "n99={}", last.n99);
    assert!(last.n95 <= last.n99);
    // Training actually progressed (metric = accuracy).
    let first = report.per_epoch.first().unwrap();
    assert!(last.test_metric >= first.test_metric);
    let cfg = ExperimentConfig::default();
    let _ = cfg; // silence unused import pattern in some configs
}

#[test]
fn h2_overlap_and_gradual_rotation_on_real_model() {
    let Some(m) = Manifest::load(&Manifest::default_dir()).ok() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let report = centralized(&rt, &m, "fcn_mnist");
    let grads: Vec<Vec<f32>> = (0..report.recorder.epochs())
        .map(|e| report.recorder.grad(e).to_vec())
        .collect();

    // Fig. 3 property: consecutive epoch gradients strongly overlap.
    let pair = pairwise_heatmap(&grads, "full");
    let mcs = mean_consecutive_similarity(&pair);
    assert!(mcs > 0.3, "consecutive similarity too low: {mcs}");

    // Fig. 2 property: every gradient overlaps some PGD strongly.
    let h = pgd_overlap_heatmap(&grads, 0.99, "full");
    assert!(h.cols < grads.len(), "PGD count not reduced");
    let overlaps = max_overlap_per_gradient(&h);
    let mean: f64 = overlaps.iter().sum::<f64>() / overlaps.len() as f64;
    assert!(mean > 0.5, "mean max-overlap {mean}");
    for (i, v) in overlaps.into_iter().enumerate() {
        assert!(v > 0.3, "epoch {i} max overlap {v}");
    }
}

#[test]
fn per_layer_analysis_uses_manifest_segments() {
    let Some(m) = Manifest::load(&Manifest::default_dir()).ok() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let report = centralized(&rt, &m, "fcn_mnist");
    let segs = report.recorder.segments.clone();
    assert!(segs.len() >= 6); // 3 dense layers x (w, b)
    for (li, seg) in segs.iter().enumerate() {
        let rows = report.recorder.layer_matrix(li);
        assert_eq!(rows.len(), report.recorder.epochs());
        assert_eq!(rows[0].len(), seg.size);
    }
}
