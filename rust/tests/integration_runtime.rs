//! Integration: load the real AOT artifacts through PJRT and check the
//! flat-parameter ABI end-to-end (requires `make artifacts`).

use fedrecycle::data::{Dataset, SynthSpec};
use fedrecycle::runtime::client::Feed;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    Manifest::load(&dir).ok()
}

macro_rules! require_artifacts {
    ($m:ident) => {
        let Some($m) = manifest() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
    };
}

#[test]
fn grad_step_executes_and_shapes_match() {
    require_artifacts!(m);
    let rt = Runtime::cpu().unwrap();
    let v = m.variant("fcn_mnist").unwrap();
    let (grad, _) = rt.load_variant(v).unwrap();
    let theta = v.load_init().unwrap();
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..v.x_len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..v.y_len()).map(|_| rng.below(10) as i32).collect();
    let (loss, g) = grad.run(&theta, Feed::F32(&x), Feed::I32(&y)).unwrap();
    assert_eq!(g.len(), v.param_count);
    assert!(loss.is_finite());
    // Random init + 10 balanced classes: loss ~ ln(10).
    assert!((loss - 10f32.ln()).abs() < 1.0, "loss={loss}");
    assert!(g.iter().all(|x| x.is_finite()));
    let norm: f64 = g.iter().map(|x| (*x as f64).powi(2)).sum();
    assert!(norm > 0.0);
}

#[test]
fn grad_step_is_deterministic() {
    require_artifacts!(m);
    let rt = Runtime::cpu().unwrap();
    let v = m.variant("cnn_mnist").unwrap();
    let (grad, _) = rt.load_variant(v).unwrap();
    let theta = v.load_init().unwrap();
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..v.x_len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..v.y_len()).map(|_| rng.below(10) as i32).collect();
    let (l1, g1) = grad.run(&theta, Feed::F32(&x), Feed::I32(&y)).unwrap();
    let (l2, g2) = grad.run(&theta, Feed::F32(&x), Feed::I32(&y)).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn sgd_on_artifact_reduces_loss() {
    require_artifacts!(m);
    let rt = Runtime::cpu().unwrap();
    let v = m.variant("fcn_mnist").unwrap();
    let (grad, _) = rt.load_variant(v).unwrap();
    let mut theta = v.load_init().unwrap();
    // Overfit one fixed synthetic batch: loss must drop hard.
    let ds = Dataset::generate(&SynthSpec::mnist(v.batch, v.batch));
    let x = &ds.train_x[..v.batch * 784];
    let y = &ds.train_y[..v.batch];
    let (loss0, _) = grad.run(&theta, Feed::F32(x), Feed::I32(y)).unwrap();
    for _ in 0..25 {
        let (_, g) = grad.run(&theta, Feed::F32(x), Feed::I32(y)).unwrap();
        for (t, gi) in theta.iter_mut().zip(&g) {
            *t -= 0.2 * gi;
        }
    }
    let (loss_n, _) = grad.run(&theta, Feed::F32(x), Feed::I32(y)).unwrap();
    assert!(
        loss_n < 0.5 * loss0,
        "SGD through artifact failed: {loss0} -> {loss_n}"
    );
}

#[test]
fn eval_metric_counts_correct_predictions() {
    require_artifacts!(m);
    let rt = Runtime::cpu().unwrap();
    let v = m.variant("fcn_mnist").unwrap();
    let (_, eval) = rt.load_variant(v).unwrap();
    let theta = v.load_init().unwrap();
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..v.x_len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..v.y_len()).map(|_| rng.below(10) as i32).collect();
    let (loss, metric) = eval.run(&theta, Feed::F32(&x), Feed::I32(&y)).unwrap();
    assert!(loss.is_finite());
    let correct = metric[0];
    assert!((0.0..=v.batch as f32).contains(&correct), "metric={correct}");
}

#[test]
fn regression_variant_roundtrip() {
    require_artifacts!(m);
    let rt = Runtime::cpu().unwrap();
    let v = m.variant("fcn_celeba").unwrap();
    assert_eq!(v.task, "reg");
    let (grad, _) = rt.load_variant(v).unwrap();
    let theta = v.load_init().unwrap();
    let mut rng = Rng::new(4);
    let x: Vec<f32> = (0..v.x_len()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<f32> = (0..v.y_len()).map(|_| rng.normal_f32(0.0, 0.5)).collect();
    let (loss, g) = grad.run(&theta, Feed::F32(&x), Feed::F32(&y)).unwrap();
    assert!(loss.is_finite() && loss >= 0.0);
    assert_eq!(g.len(), v.param_count);
}

#[test]
fn lm_variant_roundtrip() {
    require_artifacts!(m);
    let rt = Runtime::cpu().unwrap();
    let v = m.variant("transformer_lm").unwrap();
    let (grad, _) = rt.load_variant(v).unwrap();
    let theta = v.load_init().unwrap();
    let mut rng = Rng::new(5);
    let x: Vec<i32> = (0..v.x_len()).map(|_| rng.below(64) as i32).collect();
    let y: Vec<i32> = (0..v.y_len()).map(|_| rng.below(64) as i32).collect();
    let (loss, g) = grad.run(&theta, Feed::I32(&x), Feed::I32(&y)).unwrap();
    // Random tokens, vocab 64: loss ~ ln(64) ~= 4.16.
    assert!((loss - 64f32.ln()).abs() < 1.0, "lm loss {loss}");
    assert_eq!(g.len(), v.param_count);
}

#[test]
fn segments_cover_every_variant() {
    require_artifacts!(m);
    for v in &m.variants {
        let mut off = 0;
        for s in &v.segments {
            assert_eq!(s.offset, off, "{}: segment {} misaligned", v.name, s.name);
            assert_eq!(s.size, s.shape.iter().product::<usize>());
            off += s.size;
        }
        assert_eq!(off, v.param_count, "{}", v.name);
    }
}
