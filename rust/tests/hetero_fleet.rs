//! Heterogeneous-fleet scenario suite: the `testkit::profiles`
//! planet-scale layer driven end to end. A seeded [`FleetSpec`] (three
//! device tiers, power-law availability, a participation dip, layered
//! chaos) compiles to one [`Scenario`], and that scenario must run
//! **bit-identically** on every engine — the sequential and scoped-thread
//! branches of `run_fl`, the mpsc star, and both net deployments — per
//! `FL_SEED`, with matching deterministic trace streams, matching
//! ledgers, and internally consistent per-tier savings roll-ups. The
//! adaptive Theorem-1 policy rides along on every transport (it crosses
//! the wire in the Welcome frame), pinned here against the in-memory
//! reference.
//!
//! The base seed honors `FL_SEED` so CI sweeps a seed matrix; set
//! `FEDRECYCLE_TRACE=1` to dump each engine's JSONL under `target/trace/`.

use std::sync::Arc;

use fedrecycle::compress::{Compressor, Identity, WireCodec};
use fedrecycle::coordinator::accounting::{CommLedger, TierTotals};
use fedrecycle::coordinator::round::{run_fl, FlConfig, Parallelism};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::coordinator::transport::run_threaded_fl;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::metrics::RunSeries;
use fedrecycle::net::{run_mem_fl, run_tcp_fl};
use fedrecycle::obs::{self, Encoded, TraceHandle};
use fedrecycle::sim::{ChaosSpec, FaultKind, FaultPlan};
use fedrecycle::testkit::{forall, FleetSpec, Gen, Scenario};
use fedrecycle::util::json::Json;
use fedrecycle::util::rng::Rng;

const DIM: usize = 16;
const K: usize = 9;
const ROUNDS: usize = 10;
const SPREAD: f32 = 0.25;
const SIGMA: f32 = 0.03;

fn base_seed() -> u64 {
    std::env::var("FL_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn codec() -> Box<dyn Compressor> {
    Box::new(Identity)
}

/// The acceptance scenario: the planet-scale three-tier fleet with chaos
/// layered on top of the power-law availability schedule.
fn scenario(seed: u64) -> Scenario {
    let mut spec = FleetSpec::planet_scale(ROUNDS);
    spec.chaos = Some(ChaosSpec::default());
    spec.compile(seed, K, ROUNDS).unwrap()
}

/// Scenario config under the adaptive Theorem-1 policy; `apply` installs
/// the fault plan, tier map, and per-worker local-step overrides.
fn cfg(seed: u64, sc: &Scenario, trace: Option<TraceHandle>) -> FlConfig {
    let mut c = FlConfig {
        rounds: ROUNDS,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::AdaptiveDelta2 { delta2: 0.05, tau: 2 },
        sample_fraction: 1.0,
        eval_every: 4,
        seed,
        check_coherence: true,
        parallelism: Parallelism::Sequential,
        trace,
        ..Default::default()
    };
    sc.apply(&mut c).unwrap();
    c
}

/// One engine's observable output: the deterministic trace stream plus
/// the run artifacts the parity contract covers.
struct RunOut {
    stream: Vec<Encoded>,
    series: RunSeries,
    ledger: CommLedger,
    theta: Vec<f32>,
}

/// Drain one engine's recorder: optionally dump the full JSONL (CI
/// failure artifact), then return the parity-checked stream.
fn stream_of(name: &str, trace: &TraceHandle) -> Vec<Encoded> {
    let rec = trace.lock().unwrap();
    assert_eq!(rec.dropped(), 0, "{name}: ring wrapped — raise the test capacity");
    if std::env::var("FEDRECYCLE_TRACE").is_ok() {
        let dir = std::path::Path::new("target").join("trace");
        obs::sink::write_jsonl(&dir.join(format!("{name}.jsonl")), &rec).unwrap();
    }
    rec.deterministic_stream()
}

fn engine_fl(name: &str, seed: u64, par: Parallelism) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let sc = scenario(seed);
    let mut c = cfg(seed, &sc, Some(Arc::clone(&trace)));
    c.parallelism = par;
    let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, seed);
    let out = run_fl(&mut t, vec![0.0; DIM], &c, &|| codec(), name).unwrap();
    RunOut {
        stream: stream_of(name, &trace),
        series: out.series,
        ledger: out.ledger,
        theta: out.final_theta,
    }
}

fn engine_star(name: &str, seed: u64) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let sc = scenario(seed);
    let c = cfg(seed, &sc, Some(Arc::clone(&trace)));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (series, ledger, theta) = run_threaded_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
    )
    .unwrap();
    RunOut { stream: stream_of(name, &trace), series, ledger, theta }
}

fn engine_mem(name: &str, seed: u64) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let sc = scenario(seed);
    let c = cfg(seed, &sc, Some(Arc::clone(&trace)));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (series, ledger, theta) = run_mem_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
        None,
    )
    .unwrap();
    RunOut { stream: stream_of(name, &trace), series, ledger, theta }
}

fn engine_tcp(name: &str, seed: u64) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let sc = scenario(seed);
    let c = cfg(seed, &sc, Some(Arc::clone(&trace)));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (series, ledger, theta) = run_tcp_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
    )
    .unwrap();
    RunOut { stream: stream_of(name, &trace), series, ledger, theta }
}

/// Bit-diff every stream against the first, reporting the first
/// diverging event decoded rather than a wall of hex.
fn assert_streams_identical(streams: &[(&str, &[Encoded])]) {
    let (ref_name, ref_stream) = &streams[0];
    assert!(!ref_stream.is_empty(), "{ref_name}: empty deterministic stream");
    for (name, stream) in &streams[1..] {
        for (i, (a, b)) in ref_stream.iter().zip(stream.iter()).enumerate() {
            assert_eq!(
                a, b,
                "{name} diverged from {ref_name} at event {i}: {:?} vs {:?}",
                b.decode(),
                a.decode()
            );
        }
        assert_eq!(
            stream.len(),
            ref_stream.len(),
            "{name} vs {ref_name}: stream lengths differ"
        );
    }
}

/// The tier fields every engine models identically (wire bytes differ:
/// in-process engines move no frames, the net engines measure real ones).
fn modeled(t: &TierTotals) -> (&str, u64, u64, u64, u64, u64, u64, u64) {
    (
        t.name.as_str(),
        t.workers,
        t.floats_up,
        t.bits_up,
        t.floats_down,
        t.bits_down,
        t.faults,
        t.rejoins,
    )
}

fn assert_runs_match(a: &RunOut, b: &RunOut, an: &str, bn: &str) {
    assert_streams_identical(&[(an, a.stream.as_slice()), (bn, b.stream.as_slice())]);
    assert_eq!(a.theta, b.theta, "{an} vs {bn}: final theta diverged");
    assert!(a.ledger.consistent(), "{an}: ledger inconsistent");
    assert!(b.ledger.consistent(), "{bn}: ledger inconsistent");
    let (ta, tb) = (a.ledger.tier_totals(), b.ledger.tier_totals());
    assert_eq!(ta.len(), tb.len(), "{an} vs {bn}: tier row counts differ");
    for (x, y) in ta.iter().zip(&tb) {
        assert_eq!(modeled(x), modeled(y), "{an} vs {bn}: tier {} diverged", x.name);
    }
    assert_eq!(a.series.rounds.len(), b.series.rounds.len());
    for (x, y) in a.series.rounds.iter().zip(&b.series.rounds) {
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.test_metric.to_bits(), y.test_metric.to_bits(), "round {}", x.round);
        assert_eq!(x.participants, y.participants, "round {}", x.round);
        assert_eq!(x.faults, y.faults, "round {}", x.round);
        assert_eq!(x.full_sends, y.full_sends, "round {}", x.round);
        assert_eq!(x.scalar_sends, y.scalar_sends, "round {}", x.round);
    }
}

/// The tentpole acceptance: the seeded planet-scale profile (3 device
/// tiers, power-law availability, a participation window, chaos faults,
/// adaptive policy, per-worker local steps) runs bit-identically on all
/// five engine paths, per FL_SEED.
#[test]
fn planet_scale_scenario_bit_identical_across_engines() {
    let seed = 17 + base_seed();
    let runs = vec![
        ("hetero_fl_seq", engine_fl("hetero_fl_seq", seed, Parallelism::Sequential)),
        ("hetero_fl_thr", engine_fl("hetero_fl_thr", seed, Parallelism::Threads(2))),
        ("hetero_star", engine_star("hetero_star", seed)),
        ("hetero_mem", engine_mem("hetero_mem", seed)),
        ("hetero_tcp", engine_tcp("hetero_tcp", seed)),
    ];
    for (name, run) in &runs[1..] {
        assert_runs_match(&runs[0].1, run, runs[0].0, name);
    }
    // The scenario actually exercises heterogeneity: three named tier
    // rows, a non-empty fault schedule, and some absences on record.
    let tiers = runs[0].1.ledger.tier_totals();
    assert_eq!(
        tiers.iter().map(|t| t.name.as_str()).collect::<Vec<_>>(),
        vec!["fiber", "wifi", "cellular"]
    );
    assert_eq!(tiers.iter().map(|t| t.workers).sum::<u64>(), K as u64);
    assert!(runs[0].1.ledger.total_faults > 0, "scenario drew no absences");
    // The two net engines move identical frames, so even the measured
    // wire columns agree between them.
    let (mem, tcp) = (&runs[3].1, &runs[4].1);
    assert_eq!(mem.ledger.tier_totals(), tcp.ledger.tier_totals(), "mem vs tcp wire tiers");
    assert!(mem.ledger.wire_up_bytes > 0, "net run measured no uplink bytes");
}

/// Rerun determinism: the same seed reproduces the same streams and
/// ledgers on both the reference engine and the full TCP deployment.
#[test]
fn scenario_reruns_are_bit_identical() {
    let seed = 23 + base_seed();
    let a = engine_fl("rerun_seq_a", seed, Parallelism::Sequential);
    let b = engine_fl("rerun_seq_b", seed, Parallelism::Sequential);
    assert_runs_match(&a, &b, "rerun_seq_a", "rerun_seq_b");
    let c = engine_tcp("rerun_tcp_a", seed);
    let d = engine_tcp("rerun_tcp_b", seed);
    assert_runs_match(&c, &d, "rerun_tcp_a", "rerun_tcp_b");
}

/// Per-tier savings columns are internally consistent on a real
/// deployment: rows roll up exactly to the ledger totals, the savings
/// columns equal raw-minus-measured, and the round records carry the
/// same roll-up (cumulative, so the last round equals the ledger).
#[test]
fn per_tier_ledger_columns_are_internally_consistent() {
    let seed = 5 + base_seed();
    let run = engine_tcp("tier_consistency", seed);
    let ledger = &run.ledger;
    assert!(ledger.consistent());
    let tiers = ledger.tier_totals();
    assert_eq!(tiers.len(), 3);
    let sum = |f: &dyn Fn(&TierTotals) -> u64| tiers.iter().map(f).sum::<u64>();
    assert_eq!(sum(&|t| t.floats_up), ledger.total_floats);
    assert_eq!(sum(&|t| t.bits_up), ledger.total_bits);
    assert_eq!(sum(&|t| t.floats_down), ledger.down_floats);
    assert_eq!(sum(&|t| t.bits_down), ledger.down_bits);
    assert_eq!(sum(&|t| t.wire_up_bytes), ledger.wire_up_bytes);
    assert_eq!(sum(&|t| t.wire_down_bytes), ledger.wire_down_bytes);
    assert_eq!(sum(&|t| t.wire_up_raw_bytes), ledger.wire_up_raw_bytes);
    assert_eq!(sum(&|t| t.wire_down_raw_bytes), ledger.wire_down_raw_bytes);
    assert_eq!(sum(&|t| t.faults), ledger.total_faults);
    assert_eq!(sum(&|t| t.rejoins), ledger.total_rejoins);
    for t in &tiers {
        assert_eq!(
            t.savings_up_bytes,
            t.wire_up_raw_bytes.saturating_sub(t.wire_up_bytes),
            "tier {}",
            t.name
        );
        assert_eq!(
            t.savings_down_bytes,
            t.wire_down_raw_bytes.saturating_sub(t.wire_down_bytes),
            "tier {}",
            t.name
        );
    }
    // Round records carry the cumulative roll-up; the last one is the
    // ledger's final state.
    for r in &run.series.rounds {
        assert_eq!(r.tiers.len(), 3, "round {} missing tier rows", r.round);
    }
    assert_eq!(run.series.tier_summary(), &tiers[..]);

    // On the raw wire codec the raw-equivalent equals the measured bytes,
    // so every savings column is zero; a quantized session opens a gap.
    assert!(tiers.iter().all(|t| t.savings_up_bytes == 0 && t.savings_down_bytes == 0));
    let sc = scenario(seed);
    let mut q8 = cfg(seed, &sc, None);
    q8.wire_codec = WireCodec::Q8;
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (_, qledger, _) = run_tcp_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &q8,
        &|| codec(),
        "tier_q8",
    )
    .unwrap();
    assert!(qledger.consistent());
    let qtiers = qledger.tier_totals();
    assert_eq!(
        qtiers.iter().map(|t| t.wire_up_bytes).sum::<u64>(),
        qledger.wire_up_bytes
    );
    assert!(
        qtiers.iter().any(|t| t.savings_up_bytes > 0),
        "q8 session reported no per-tier uplink savings"
    );
}

/// The adaptive Theorem-1 policy over TCP (with per-worker tau overrides
/// riding the Welcome frame) matches the in-memory reference bit for bit
/// — at a generous Delta^2 where every post-bootstrap uplink is a scalar
/// LBC, and at a tight one where the mix leans on full refreshes.
#[test]
fn adaptive_policy_over_tcp_matches_in_memory_reference() {
    let seed = 31 + base_seed();
    // No chaos here: this pins the policy wire encoding, not the fault
    // machinery (the chaos matrix covers the combination above).
    let sc = FleetSpec::planet_scale(ROUNDS).compile(seed, K, ROUNDS).unwrap();
    for (delta2, expect_scalars) in [(50.0, true), (1e-4, false)] {
        let mut reference = cfg(seed, &sc, None);
        reference.faults = None;
        reference.policy = ThresholdPolicy::AdaptiveDelta2 { delta2, tau: 2 };
        let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, seed);
        let seq = run_fl(&mut t, vec![0.0; DIM], &reference, &|| codec(), "adaptive_seq")
            .unwrap();
        let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
        let weights = eval.weights();
        let (series, ledger, theta) = run_tcp_fl(
            |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
            &mut eval,
            vec![0.0; DIM],
            weights,
            &reference,
            &|| codec(),
            "adaptive_tcp",
        )
        .unwrap();
        assert_eq!(seq.final_theta, theta, "delta2={delta2}: theta diverged");
        assert_eq!(seq.ledger.total_floats, ledger.total_floats, "delta2={delta2}");
        assert_eq!(seq.ledger.scalar_msgs, ledger.scalar_msgs, "delta2={delta2}");
        assert_eq!(seq.ledger.full_msgs, ledger.full_msgs, "delta2={delta2}");
        for (a, b) in seq.series.rounds.iter().zip(&series.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "delta2={delta2} round {}",
                a.round
            );
            assert_eq!(a.scalar_sends, b.scalar_sends, "delta2={delta2} round {}", a.round);
        }
        // Deterministic shape guarantees only: every worker's bootstrap
        // uplink is a full refresh, and at Delta^2 = 50 the threshold
        // exceeds 1 for these toy gradients, so everything after the
        // bootstrap is a scalar LBC. (The tight regime's exact mix
        // depends on how collinear the mock gradients run — the parity
        // assertions above are its pin.)
        assert!(ledger.full_msgs >= K as u64, "delta2={delta2}: missing bootstrap refreshes");
        if expect_scalars {
            assert!(
                ledger.scalar_msgs > ledger.full_msgs,
                "delta2={delta2}: scalar steady state never engaged"
            );
        }
    }
}

/// Generator for federation shapes `(seed, workers, rounds)`. Seeds stay
/// below 2^53 so a plan's JSON round-trip (numbers are f64) is exact.
struct ShapeGen;

impl Gen for ShapeGen {
    type Value = (u64, usize, usize);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (rng.next_u64() >> 12, 1 + rng.below(12), 1 + rng.below(30))
    }

    fn shrink(&self, &(seed, w, r): &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if w > 1 {
            out.push((seed, w / 2, r));
        }
        if r > 1 {
            out.push((seed, w, r / 2));
        }
        if seed != 0 {
            out.push((0, w, r));
        }
        out
    }
}

fn json_round_trip(plan: &FaultPlan) -> Result<(), String> {
    let text = Json::to_string(&plan.to_json());
    let parsed = Json::parse(&text).map_err(|e| format!("reparse failed: {e:#}"))?;
    let back =
        FaultPlan::from_json(&parsed).map_err(|e| format!("reload failed: {e:#}"))?;
    if &back != plan {
        return Err("JSON round-trip changed the plan".into());
    }
    Ok(())
}

/// `FaultPlan::random`: same seed => identical plan, exact JSON
/// round-trip, and every event inside `[0, rounds)` with a non-empty
/// `[from, until)` span on a real worker.
#[test]
fn prop_random_plans_deterministic_and_json_exact() {
    let spec = ChaosSpec::default();
    forall(0xF1EE7 + base_seed(), 40, &ShapeGen, |&(seed, workers, rounds)| {
        let plan = FaultPlan::random(seed, workers, rounds, &spec);
        if plan != FaultPlan::random(seed, workers, rounds, &spec) {
            return Err("same seed produced different plans".into());
        }
        for e in &plan.events {
            if e.worker >= workers {
                return Err(format!("event worker {} out of range {workers}", e.worker));
            }
            if e.from >= e.until || e.until > rounds {
                return Err(format!(
                    "event span [{}, {}) outside [0, {rounds})",
                    e.from, e.until
                ));
            }
        }
        json_round_trip(&plan)
    });
}

/// Profile compilation: deterministic per seed, availability inside the
/// power-law support `[floor, 1]`, coalesced in-range absence spans, and
/// the compiled plan (events + tier link profiles) survives JSON exactly.
#[test]
fn prop_profile_compilation_invariants() {
    forall(0x9EA7 + base_seed(), 30, &ShapeGen, |&(seed, workers, rounds)| {
        let spec = FleetSpec::planet_scale(rounds);
        let sc = spec.compile(seed, workers, rounds).map_err(|e| format!("{e:#}"))?;
        if sc != spec.compile(seed, workers, rounds).map_err(|e| format!("{e:#}"))? {
            return Err("same seed compiled different scenarios".into());
        }
        for (w, &a) in sc.availability.iter().enumerate() {
            if !(spec.floor..=1.0).contains(&a) {
                return Err(format!(
                    "worker {w} availability {a} outside [{}, 1]",
                    spec.floor
                ));
            }
        }
        for e in &sc.plan.events {
            if e.kind != FaultKind::Disconnect {
                return Err(format!("unexpected kind {:?}", e.kind));
            }
            if e.worker >= workers || e.from >= e.until || e.until > rounds {
                return Err(format!(
                    "event (worker {}, [{}, {})) outside shape ({workers}, {rounds})",
                    e.worker, e.from, e.until
                ));
            }
        }
        if !sc.tiers.well_formed() || sc.tiers.of.len() != workers {
            return Err("malformed tier map".into());
        }
        if sc.tau.len() != workers || sc.tau.iter().any(|&t| t == 0) {
            return Err("malformed tau overrides".into());
        }
        json_round_trip(&sc.plan)
    });
}
