//! Wire protocol v3 integration suite.
//!
//! What lives here (unit-level coverage is in `net::{wire, quant, server,
//! client}` tests):
//!
//! * Quantized TCP deployments end to end: a `q8` run must cut the
//!   *measured* Round-broadcast wire bytes by >= 3x against the ledger's
//!   raw-equivalent column while staying within loss tolerance of the
//!   raw sequential reference; `f16` must save bytes with a much tighter
//!   loss bound. A quantized run completing at all is also the
//!   delta-reconstruction exactness check: the client kills the session
//!   on any base mismatch, so every post-round-0 broadcast arriving as a
//!   delta proves both ends track the same reconstruction.
//! * Mixed-version fleet smoke: a raw-preferring worker (the v2 byte
//!   surface) and a `q8` worker served by the same quantized server.
//! * Chunked frame streaming over links, plus its corruption suite
//!   (out-of-order, interrupted, oversized, bit-flipped streams).
//! * Token-authenticated rejoin over real TCP: wrong token and wrong dim
//!   are rejected at the handshake, the right token is re-welcomed.
//! * The serve-phase recv deadline: a server that goes silent mid-round
//!   without closing its socket must not wedge the worker — the worker
//!   rejoins and finishes (the `connect_worker_with_retry` bugfix pin;
//!   before the fix this test hangs forever).

use std::net::TcpListener;
use std::time::Duration;

use fedrecycle::compress::{Identity, WireCodec};
use fedrecycle::coordinator::messages::Payload;
use fedrecycle::coordinator::round::{run_fl, FlConfig, FlOutcome, Parallelism};
use fedrecycle::coordinator::trainer::{LocalTrainer, MockTrainer};
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::net::server::session_token;
use fedrecycle::net::wire::{self, Frame};
use fedrecycle::net::{
    connect_worker_with_retry, recv_frame, run_server_rounds_elastic, run_tcp_fl,
    send_frame, Acceptor, Link, MemLink, ReconnectCfg, TcpLink,
};

const SPREAD: f32 = 0.25;
const SIGMA: f32 = 0.03;

fn cfg(delta: f64, seed: u64, codec: WireCodec) -> FlConfig {
    FlConfig {
        rounds: 10,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(delta),
        sample_fraction: 1.0,
        eval_every: 1,
        seed,
        check_coherence: false,
        parallelism: Parallelism::Sequential,
        wire_codec: codec,
        ..Default::default()
    }
}

fn sequential(dim: usize, k: usize, c: &FlConfig) -> FlOutcome {
    let mut t = MockTrainer::new(dim, k, SPREAD, SIGMA, c.seed);
    run_fl(&mut t, vec![0.0; dim], c, &|| Box::new(Identity), "seq").unwrap()
}

fn deployed_tcp(
    dim: usize,
    k: usize,
    c: &FlConfig,
) -> (fedrecycle::metrics::RunSeries, fedrecycle::coordinator::CommLedger, Vec<f32>) {
    let mut eval = MockTrainer::new(dim, k, SPREAD, 0.0, c.seed);
    let weights = eval.weights();
    run_tcp_fl(
        |_id| MockTrainer::new(dim, k, SPREAD, SIGMA, c.seed),
        &mut eval,
        vec![0.0; dim],
        weights,
        c,
        &|| Box::new(Identity),
        "tcp",
    )
    .unwrap()
}

fn final_test_loss(series: &fedrecycle::metrics::RunSeries) -> f64 {
    series.rounds.last().unwrap().test_loss
}

/// The headline acceptance number: a q8 session moves >= 3x fewer
/// measured bytes per Round broadcast than the same frames would cost
/// raw, and the lossy codec stays within loss tolerance of the raw
/// reference (error feedback and delta bases keep the error bounded
/// instead of compounding).
#[test]
fn q8_tcp_run_cuts_round_broadcast_bytes_3x_within_loss_tolerance() {
    let dim = 512;
    let k = 3;
    let raw_ref = sequential(dim, k, &cfg(-1.0, 41, WireCodec::Raw));
    let c = cfg(-1.0, 41, WireCodec::Q8);
    let (series, ledger, theta) = deployed_tcp(dim, k, &c);
    assert_eq!(theta.len(), dim);
    assert!(ledger.consistent());

    // Downlink: every broadcast was a RoundQ (dense round 0, deltas
    // after); the raw-equivalent column records what raw Round frames
    // would have measured.
    assert!(
        ledger.wire_down_raw_bytes >= 3 * ledger.wire_down_bytes,
        "q8 Round broadcasts saved less than 3x: {} raw-equivalent vs {} measured",
        ledger.wire_down_raw_bytes,
        ledger.wire_down_bytes
    );
    // Uplink: vanilla FL sends a full gradient every round, all UpdateQ.
    assert!(
        ledger.wire_up_raw_bytes >= 3 * ledger.wire_up_bytes,
        "q8 uplinks saved less than 3x: {} vs {}",
        ledger.wire_up_raw_bytes,
        ledger.wire_up_bytes
    );
    let (up_saved, down_saved) = ledger.wire_savings();
    assert!(up_saved > 0 && down_saved > 0);
    // The per-round series snapshots the same totals (JSON summary path).
    let last = series.rounds.last().unwrap();
    assert_eq!(last.wire_up_raw_bytes, ledger.wire_up_raw_bytes);
    assert_eq!(last.wire_down_raw_bytes, ledger.wire_down_raw_bytes);

    // Lossy, but bounded: the q8 run's final test loss tracks the raw
    // sequential reference.
    let raw_loss = final_test_loss(&raw_ref.series);
    let q8_loss = final_test_loss(&series);
    assert!(
        (q8_loss - raw_loss).abs() <= 0.25 * raw_loss.abs() + 1e-2,
        "q8 loss {q8_loss} drifted from raw {raw_loss}"
    );
}

/// f16 halves the mantissa, not the byte count as aggressively as q8 —
/// assert real savings and a much tighter loss bound (~3 decimal digits
/// survive the wire).
#[test]
fn f16_tcp_run_saves_bytes_with_tight_loss_tolerance() {
    let dim = 384;
    let k = 2;
    let raw_ref = sequential(dim, k, &cfg(-1.0, 43, WireCodec::Raw));
    let (series, ledger, _theta) = deployed_tcp(dim, k, &cfg(-1.0, 43, WireCodec::F16));
    let (up_saved, down_saved) = ledger.wire_savings();
    assert!(up_saved > 0, "f16 uplink saved nothing");
    assert!(down_saved > 0, "f16 downlink saved nothing");
    let raw_loss = final_test_loss(&raw_ref.series);
    let f16_loss = final_test_loss(&series);
    assert!(
        (f16_loss - raw_loss).abs() <= 0.02 * raw_loss.abs() + 1e-3,
        "f16 loss {f16_loss} drifted from raw {raw_loss}"
    );
}

/// LBGM on a quantized session: scalar uplinks ride the plain v2 Update
/// frame while refreshes are quantized, and the resynced LBG copies keep
/// the look-back coherent (the run completes and keeps saving bytes).
#[test]
fn q8_session_interoperates_with_lbgm_scalars() {
    let dim = 256;
    let k = 3;
    let mut c = cfg(0.4, 47, WireCodec::Q8);
    c.rounds = 12;
    let (series, ledger, _theta) = deployed_tcp(dim, k, &c);
    assert!(ledger.scalar_msgs > 0, "LBGM path never engaged");
    assert!(ledger.full_msgs > 0);
    // Broadcasts are quantized regardless of the uplink mix.
    assert!(ledger.wire_down_raw_bytes >= 3 * ledger.wire_down_bytes);
    // Scalar Update frames count identically on both uplink columns, so
    // the uplink saving comes from the refreshes alone — still nonzero.
    assert!(ledger.wire_savings().0 > 0);
    let losses: Vec<f64> = series.rounds.iter().map(|r| r.test_loss).collect();
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "quantized LBGM run failed to make progress: {losses:?}"
    );
}

/// Mixed-version smoke: one raw-preferring worker (exactly the v2 byte
/// surface on the wire) and one q8 worker, served concurrently by a
/// quantized server. Negotiation is per session, so both finish the run.
#[test]
fn mixed_raw_and_q8_fleet_completes_on_one_server() {
    let dim = 256;
    let k = 2;
    let c = cfg(-1.0, 53, WireCodec::Q8);
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let mut handles = Vec::new();
    for (id, pref) in [(0usize, WireCodec::Raw), (1usize, WireCodec::Q8)] {
        let seed = c.seed;
        handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut trainer = MockTrainer::new(dim, k, SPREAD, SIGMA, seed);
            connect_worker_with_retry(
                addr,
                id,
                &mut trainer,
                Box::new(Identity),
                pref,
                &ReconnectCfg::default(),
            )
        }));
    }
    let acceptor =
        Acceptor::spawn(listener, k, dim, &c, Duration::from_secs(30)).unwrap();
    let (mut links, codecs) = acceptor.wait_for_fleet(k).unwrap();
    assert_eq!(
        codecs,
        vec![WireCodec::Raw, WireCodec::Q8],
        "per-session negotiation lost a codec"
    );
    let mut eval = MockTrainer::new(dim, k, SPREAD, 0.0, c.seed);
    let weights = eval.weights();
    let (_series, ledger, theta) = run_server_rounds_elastic(
        &mut links,
        codecs,
        &mut eval,
        vec![0.0; dim],
        weights,
        &c,
        Duration::from_secs(60),
        "mixed",
        None,
    )
    .unwrap();
    drop(acceptor);
    for h in handles {
        assert_eq!(h.join().unwrap().unwrap(), c.rounds, "a worker missed rounds");
    }
    assert_eq!(theta.len(), dim);
    // Worker 0's frames count equally on both columns; worker 1's save —
    // the gap exists but is smaller than an all-q8 fleet's.
    let (up_saved, down_saved) = ledger.wire_savings();
    assert!(up_saved > 0 && down_saved > 0, "mixed fleet saved nothing");
    assert!(ledger.wire_up_raw_bytes > ledger.wire_up_bytes);
    assert!(
        ledger.wire_down_raw_bytes < 2 * ledger.wire_down_bytes,
        "raw worker's broadcasts should halve the fleet-wide ratio"
    );
}

/// A frame larger than CHUNK_DATA_LEN streams as bounded chunks and
/// reassembles exactly; the corruption suite then breaks the stream in
/// every way the assembler guards against.
#[test]
fn chunked_frames_round_trip_and_reject_corruption() {
    // 300k params * 4 B > the 1 MiB chunk bound: send_frame must stream.
    let dim = 300_000;
    let theta: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.001).cos()).collect();
    let frame = Frame::Round { t: 7, theta: theta.clone() };
    assert!(
        frame.chunk_frames(wire::CHUNK_DATA_LEN).is_some(),
        "test frame too small to exercise chunking"
    );
    let max_total = wire::HEADER_LEN + wire::session_max_payload(dim) + wire::CHECKSUM_LEN;
    let (mut a, mut b) = MemLink::pair();
    let sent = send_frame(&mut a, &frame).unwrap();
    assert!(sent > frame.wire_bytes(), "chunk framing overhead went missing");
    match recv_frame(&mut b, max_total).unwrap() {
        Frame::Round { t, theta: got } => {
            assert_eq!(t, 7);
            assert_eq!(got, theta, "chunked reassembly is not byte-exact");
        }
        other => panic!("wrong frame {other:?}"),
    }

    // Build a small chunked stream by hand to corrupt it.
    let small = Frame::Round { t: 1, theta: vec![0.5; 2000] };
    let chunks = small.chunk_frames(1024).unwrap();
    assert!(chunks.len() >= 3);

    // Out of order: the stream must start at offset 0.
    let (mut a, mut b) = MemLink::pair();
    a.send(&chunks[1]).unwrap();
    let err = recv_frame(&mut b, max_total).unwrap_err().to_string();
    assert!(err.contains("offset"), "{err}");

    // Interrupted: a non-chunk frame mid-stream kills the assembly.
    let (mut a, mut b) = MemLink::pair();
    a.send(&chunks[0]).unwrap();
    a.send(&Frame::Shutdown).unwrap();
    assert!(recv_frame(&mut b, max_total).is_err());

    // Oversized: a claimed total beyond the session cap is rejected
    // before any allocation-by-attacker.
    let (mut a, mut b) = MemLink::pair();
    a.send(&Frame::Chunk { total: u64::MAX / 2, offset: 0, data: vec![0u8; 8] })
        .unwrap();
    assert!(recv_frame(&mut b, max_total).is_err());

    // Bit flip inside the reassembled bytes: each chunk frame is valid,
    // but the inner frame's checksum must catch the flip.
    let mut inner = small.to_bytes();
    let mid = inner.len() / 2;
    inner[mid] ^= 0x40;
    let total = inner.len() as u64;
    let (mut a, mut b) = MemLink::pair();
    let mut off = 0usize;
    for piece in inner.chunks(1024) {
        a.send(&Frame::Chunk { total, offset: off as u64, data: piece.to_vec() })
            .unwrap();
        off += piece.len();
    }
    let err = recv_frame(&mut b, max_total).unwrap_err().to_string();
    assert!(err.contains("checksum"), "{err}");
}

/// The acceptance pin, over real TCP: a duplicate `Rejoin3` presenting
/// the wrong session token is rejected at the handshake (the connection
/// dies without a Welcome), the right token is re-welcomed, and a
/// right-token rejoin with the wrong model dim is rejected too.
#[test]
fn wrong_token_rejoin_is_rejected_over_tcp() {
    let dim = 16;
    let c = cfg(-1.0, 59, WireCodec::Q8);
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let acceptor = Acceptor::spawn(listener, 1, dim, &c, Duration::from_secs(10)).unwrap();

    // The real worker 0 handshakes on protocol v3 and learns its token.
    let mut real = TcpLink::new(std::net::TcpStream::connect(addr).unwrap()).unwrap();
    real.set_recv_timeout(Some(Duration::from_secs(10))).unwrap();
    real.send(&Frame::Hello3 { worker: 0, dim: dim as u64, codec: WireCodec::Q8.to_wire() })
        .unwrap();
    let token = match real.recv().unwrap() {
        Frame::Welcome3 { token, codec, .. } => {
            assert_eq!(codec, WireCodec::Q8.to_wire());
            token
        }
        other => panic!("expected Welcome3, got {other:?}"),
    };
    assert_eq!(token, session_token(c.seed, 0), "token derivation drifted");
    let (_links, codecs) = acceptor.wait_for_fleet(1).unwrap();
    assert_eq!(codecs, vec![WireCodec::Q8]);

    // An imposter replays the rejoin with a flipped token: no Welcome,
    // connection closed, seat untouched.
    let mut imposter = TcpLink::new(std::net::TcpStream::connect(addr).unwrap()).unwrap();
    imposter.set_recv_timeout(Some(Duration::from_secs(10))).unwrap();
    imposter
        .send(&Frame::Rejoin3 { worker: 0, last_round: 0, dim: dim as u64, token: token ^ 1 })
        .unwrap();
    assert!(imposter.recv().is_err(), "imposter with a bad token got a reply");

    // Right token, wrong dim: also rejected at the handshake.
    let mut shrunk = TcpLink::new(std::net::TcpStream::connect(addr).unwrap()).unwrap();
    shrunk.set_recv_timeout(Some(Duration::from_secs(10))).unwrap();
    shrunk
        .send(&Frame::Rejoin3 { worker: 0, last_round: 0, dim: dim as u64 + 1, token })
        .unwrap();
    assert!(shrunk.recv().is_err(), "dim-mismatched rejoin got a reply");

    // The genuine rejoin is re-welcomed with the same token.
    let mut back = TcpLink::new(std::net::TcpStream::connect(addr).unwrap()).unwrap();
    back.set_recv_timeout(Some(Duration::from_secs(10))).unwrap();
    back.send(&Frame::Rejoin3 { worker: 0, last_round: 0, dim: dim as u64, token })
        .unwrap();
    match back.recv().unwrap() {
        Frame::Welcome3 { token: t2, .. } => assert_eq!(t2, token),
        other => panic!("expected Welcome3 on genuine rejoin, got {other:?}"),
    }
}

/// The serve-phase deadline pin: a server that stops mid-round *without
/// closing its socket* (SIGKILL/partition semantics) must not wedge the
/// worker. With the bounded serve recv deadline the worker maps the
/// silence to a lost link, reconnects, rejoins with its true cursor, and
/// finishes the run. Before the bugfix (recv timeout cleared after the
/// handshake) this test hangs forever on the second accept.
#[test]
fn worker_rejoins_after_server_goes_silent_mid_round() {
    let dim = 8;
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let retry = ReconnectCfg {
        max_attempts: 10,
        initial_backoff: Duration::from_millis(10),
        max_backoff: Duration::from_millis(100),
        handshake_timeout: Duration::from_secs(10),
        serve_timeout: Duration::from_millis(300),
    };
    let client = std::thread::spawn(move || -> anyhow::Result<usize> {
        let mut trainer = MockTrainer::new(dim, 1, SPREAD, SIGMA, 5);
        connect_worker_with_retry(
            addr,
            0,
            &mut trainer,
            Box::new(Identity),
            WireCodec::Raw,
            &retry,
        )
    });

    // Scripted server, connection 1: welcome, drive round 0, then go
    // silent while HOLDING the socket open.
    let (s1, _) = listener.accept().unwrap();
    let mut conn1 = TcpLink::new(s1).unwrap();
    conn1.set_recv_timeout(Some(Duration::from_secs(10))).unwrap();
    match conn1.recv().unwrap() {
        Frame::Hello { worker: 0, dim: d } => assert_eq!(d, dim as u64),
        other => panic!("expected Hello, got {other:?}"),
    }
    conn1
        .send(&Frame::Welcome { dim: dim as u64, tau: 1, eta: 0.05, delta: 2.0 })
        .unwrap();
    conn1.send(&Frame::Round { t: 0, theta: vec![0.0; dim] }).unwrap();
    assert!(matches!(conn1.recv().unwrap(), Frame::Update(_)));
    // ...silence. conn1 stays alive in scope; the worker's 300 ms serve
    // deadline must fire and bring it back to accept().

    let (s2, _) = listener.accept().unwrap();
    let mut conn2 = TcpLink::new(s2).unwrap();
    conn2.set_recv_timeout(Some(Duration::from_secs(10))).unwrap();
    match conn2.recv().unwrap() {
        Frame::Rejoin { worker, last_round } => {
            assert_eq!(worker, 0);
            assert_eq!(last_round, 0, "rejoin must carry the true cursor");
        }
        other => panic!("expected Rejoin, got {other:?}"),
    }
    conn2
        .send(&Frame::Welcome { dim: dim as u64, tau: 1, eta: 0.05, delta: 2.0 })
        .unwrap();
    conn2.send(&Frame::Round { t: 1, theta: vec![0.01; dim] }).unwrap();
    match conn2.recv().unwrap() {
        Frame::Update(m) => {
            assert_eq!(m.round, 1);
            assert!(
                matches!(m.payload, Payload::Full { .. }),
                "first post-rejoin uplink must be a forced full refresh"
            );
        }
        other => panic!("expected Update, got {other:?}"),
    }
    conn2.send(&Frame::Shutdown).unwrap();
    drop(conn1);
    assert_eq!(client.join().unwrap().unwrap(), 2, "worker lost a round across the rejoin");
}
