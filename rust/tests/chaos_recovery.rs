//! Chaos-recovery suite: for any seeded `FaultPlan`, every deployment
//! engine must (a) complete the run, (b) aggregate the fault-free subset
//! of updates bit-identically to a sequential run restricted to those
//! participants (which is exactly `run_fl` driven by the same plan), and
//! (c) keep `CommLedger::consistent()`. Same plan + same seed must also
//! yield identical ledgers across repeated runs.
//!
//! The base seed honors `FL_SEED` so CI can sweep a seed matrix.

use fedrecycle::compress::{Compressor, Identity, TopK};
use fedrecycle::coordinator::round::{run_fl, FlConfig, FlOutcome, Parallelism};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::coordinator::transport::run_threaded_fl;
use fedrecycle::coordinator::CommLedger;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::metrics::RunSeries;
use fedrecycle::net::{run_mem_fl, run_tcp_fl};
use fedrecycle::sim::{ChaosSpec, FaultPlan};
use fedrecycle::testkit::scenarios;

const DIM: usize = 16;
const K: usize = 4;
const ROUNDS: usize = 8;
const SPREAD: f32 = 0.25;
const SIGMA: f32 = 0.03;

fn base_seed() -> u64 {
    std::env::var("FL_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn cfg(delta: f64, fraction: f64, seed: u64, faults: Option<FaultPlan>) -> FlConfig {
    FlConfig {
        rounds: ROUNDS,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(delta),
        sample_fraction: fraction,
        eval_every: 4,
        seed,
        check_coherence: true,
        parallelism: Parallelism::Sequential,
        faults,
        ..Default::default()
    }
}

/// The sequential partial-participation reference: `run_fl` driven by the
/// same plan — workers absent under the plan never train their faulted
/// rounds, exactly like a run restricted to the arrived participants.
fn sequential(cfg: &FlConfig, codec: &dyn Fn() -> Box<dyn Compressor>) -> FlOutcome {
    let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, cfg.seed);
    run_fl(&mut t, vec![0.0; DIM], cfg, codec, "seq").unwrap()
}

fn deployed_mem(
    cfg: &FlConfig,
    codec: &dyn Fn() -> Box<dyn Compressor>,
) -> (RunSeries, CommLedger, Vec<f32>) {
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, cfg.seed);
    let weights = eval.weights();
    run_mem_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, cfg.seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        cfg,
        codec,
        "mem",
        None,
    )
    .unwrap()
}

fn deployed_tcp(
    cfg: &FlConfig,
    codec: &dyn Fn() -> Box<dyn Compressor>,
) -> (RunSeries, CommLedger, Vec<f32>) {
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, cfg.seed);
    let weights = eval.weights();
    run_tcp_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, cfg.seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        cfg,
        codec,
        "tcp",
    )
    .unwrap()
}

/// Everything observable except wall-clock and wire bytes must match
/// bit-for-bit between the sequential reference and a chaos deployment —
/// including the new participation and fault columns.
fn assert_matches_reference(seq: &FlOutcome, net: &(RunSeries, CommLedger, Vec<f32>)) {
    let (series, ledger, theta) = net;
    assert_eq!(&seq.final_theta, theta, "final theta diverged");
    assert_eq!(seq.ledger.total_floats, ledger.total_floats);
    assert_eq!(seq.ledger.total_bits, ledger.total_bits);
    assert_eq!(seq.ledger.scalar_msgs, ledger.scalar_msgs);
    assert_eq!(seq.ledger.full_msgs, ledger.full_msgs);
    assert_eq!(seq.ledger.total_down_floats(), ledger.total_down_floats());
    assert_eq!(seq.ledger.total_faults, ledger.total_faults, "fault totals diverged");
    assert_eq!(
        seq.ledger.total_rejoins, ledger.total_rejoins,
        "rejoin totals diverged"
    );
    assert!(ledger.consistent(), "deployment ledger inconsistent");
    assert!(seq.ledger.consistent(), "sequential ledger inconsistent");
    for w in 0..K {
        assert_eq!(seq.ledger.worker_floats(w), ledger.worker_floats(w), "worker {w}");
        assert_eq!(seq.ledger.worker_faults(w), ledger.worker_faults(w), "worker {w}");
        assert_eq!(seq.ledger.worker_rejoins(w), ledger.worker_rejoins(w), "worker {w}");
        assert_eq!(
            seq.ledger.worker_down_floats(w),
            ledger.worker_down_floats(w),
            "worker {w}"
        );
    }
    assert_eq!(seq.series.rounds.len(), series.rounds.len());
    for (a, b) in seq.series.rounds.iter().zip(&series.rounds) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.test_metric.to_bits(), b.test_metric.to_bits());
        assert_eq!(a.floats_up, b.floats_up, "round {}", a.round);
        assert_eq!(a.participants, b.participants, "round {}", a.round);
        assert_eq!(a.faults, b.faults, "round {}", a.round);
        assert_eq!(a.full_sends, b.full_sends, "round {}", a.round);
        assert_eq!(a.scalar_sends, b.scalar_sends, "round {}", a.round);
    }
}

/// The acceptance scenario: a TCP-loopback run with a plan dropping 1 of 4
/// workers in rounds 2–3 completes, reports `participants < total` in
/// exactly those rounds, matches the sequential partial-participation
/// reference bit-for-bit, and reproduces identical ledgers across two
/// runs of the same plan + seed.
#[test]
fn acceptance_drop_one_of_four_over_tcp() {
    let seed = 11 + base_seed();
    let plan = scenarios::drop_worker(2, 2, 4);
    let c = cfg(0.4, 1.0, seed, Some(plan));
    let seq = sequential(&c, &|| Box::new(Identity));
    let a = deployed_tcp(&c, &|| Box::new(Identity));
    let b = deployed_tcp(&c, &|| Box::new(Identity));

    for (t, r) in a.0.rounds.iter().enumerate() {
        if t == 2 || t == 3 {
            assert_eq!(r.participants, K - 1, "round {t} should miss worker 2");
            assert_eq!(r.faults, 1, "round {t}");
        } else {
            assert_eq!(r.participants, K, "round {t} should be full");
            assert_eq!(r.faults, 0, "round {t}");
        }
    }
    assert_eq!(a.1.total_faults, 2);
    assert_eq!(a.1.worker_faults(2), 2);
    assert_matches_reference(&seq, &a);

    // Same plan + same seed => identical ledgers across runs, measured
    // wire bytes included.
    assert_eq!(a.1.total_floats, b.1.total_floats);
    assert_eq!(a.1.total_bits, b.1.total_bits);
    assert_eq!(a.1.wire_up_bytes, b.1.wire_up_bytes);
    assert_eq!(a.1.wire_down_bytes, b.1.wire_down_bytes);
    assert_eq!(a.1.total_faults, b.1.total_faults);
    assert_eq!(a.2, b.2, "theta diverged between identical chaos runs");
    // Faults save uplink wire bytes but the swallowed broadcast still
    // counts as sent.
    let clean = deployed_tcp(&cfg(0.4, 1.0, seed, None), &|| Box::new(Identity));
    assert!(a.1.wire_up_bytes < clean.1.wire_up_bytes);
    assert_eq!(a.1.wire_down_bytes, clean.1.wire_down_bytes);
}

/// The elastic-recovery acceptance scenario (tentpole + satellite test):
/// worker 2's connection is *genuinely severed* in round 2 — the server
/// side tears the socket down, the client's reconnect loop re-handshakes
/// with a protocol-v2 `Rejoin` — and the worker is re-seated in time for
/// round 4. The run must (a) complete with worker 2 absent exactly in
/// rounds 2–3, (b) count exactly one rejoin for it, (c) match the
/// fault-restricted sequential reference bit-for-bit (which models the
/// same schedule via `FaultPlan::rejoins_at`), and (d) send a forced
/// `Full` as the worker's first post-rejoin uplink — LBG coherence is
/// re-established by a dense refresh, visible in round 4's uplink float
/// volume (and pinned exactly at the client level in `net::client`'s
/// unit tests).
#[test]
fn severed_worker_rejoins_and_matches_the_sequential_reference() {
    let seed = 3 + base_seed();
    let plan = scenarios::disconnect_then_rejoin(2, 2, 4);
    // delta = 0.9: permissive enough that steady-state rounds go scalar,
    // so a spurious (or missing) forced refresh is visible in full_sends.
    let c = cfg(0.9, 1.0, seed, Some(plan));
    let seq = sequential(&c, &|| Box::new(Identity));
    let net = deployed_tcp(&c, &|| Box::new(Identity));
    assert_matches_reference(&seq, &net);

    let (series, ledger, _theta) = &net;
    assert_eq!(ledger.total_rejoins, 1, "exactly one rejoin expected");
    assert_eq!(ledger.worker_rejoins(2), 1);
    assert_eq!(ledger.worker_faults(2), 2, "absent in rounds 2 and 3");
    for (t, r) in series.rounds.iter().enumerate() {
        if t == 2 || t == 3 {
            assert_eq!(r.participants, K - 1, "round {t} should miss worker 2");
            assert_eq!(r.faults, 1, "round {t}");
        } else {
            assert_eq!(r.participants, K, "round {t} should be full");
            assert_eq!(r.faults, 0, "round {t}");
        }
    }
    // (d) The first post-rejoin uplink is a forced full refresh. The
    // client-level pin lives in `net::client`'s unit tests (a rejoined
    // session must uplink `Full` even when the policy says scalar); here
    // the deployment-level evidence is round 4's uplink volume: at least
    // one dense gradient (worker 2's forced refresh) rode along with the
    // other workers' messages. floats_up is cumulative, so the round-4
    // delta is exactly this round's uplink floats.
    let round4_floats = series.rounds[4].floats_up - series.rounds[3].floats_up;
    assert!(
        round4_floats >= DIM as u64 + (K as u64 - 1),
        "round 4 uplink carried {round4_floats} floats — no room for worker 2's \
         forced dense refresh"
    );
    assert!(series.rounds[4].full_sends >= 1, "no refresh at all in round 4");
    assert!(ledger.consistent());
}

/// Property (a)+(b)+(c) over a sweep of seeded random plans on the
/// MemLink deployment, with every fault kind in play.
#[test]
fn prop_random_plans_match_the_sequential_reference() {
    let spec = ChaosSpec {
        p_drop: 0.12,
        p_delay: 0.08,
        p_disconnect: 0.08,
        p_corrupt: 0.06,
        max_span: 2,
        delay_ms: 1,
    };
    for case in 0..5u64 {
        let seed = base_seed().wrapping_mul(1000) + 31 + case;
        let plan = FaultPlan::random(seed, K, ROUNDS, &spec);
        let faults = plan.scheduled_slots(K, ROUNDS);
        let c = cfg(0.4, 1.0, seed, Some(plan));
        let seq = sequential(&c, &|| Box::new(Identity));
        let net = deployed_mem(&c, &|| Box::new(Identity));
        assert_matches_reference(&seq, &net);
        assert_eq!(
            net.1.total_faults as usize, faults,
            "case {case}: full participation must observe every scheduled fault"
        );
    }
}

/// Sampling composes with faults: only faults hitting a *sampled* worker
/// count, and the plug-and-play TopK codec stays bit-exact.
#[test]
fn sampled_topk_run_survives_chaos() {
    let seed = 23 + base_seed();
    let plan = scenarios::flaky_fleet(seed, K, ROUNDS, 0.5);
    let c = cfg(0.3, 0.6, seed, Some(plan));
    let codec: &dyn Fn() -> Box<dyn Compressor> = &|| Box::new(TopK::new(0.5));
    let seq = sequential(&c, codec);
    let net = deployed_mem(&c, codec);
    assert_matches_reference(&seq, &net);
    for r in &net.0.rounds {
        assert_eq!(r.participants + r.faults, 3, "3 of 4 sampled per round");
    }
}

/// A round that loses every sampled worker still commits: the model is
/// untouched, the record shows zero participants, and training resumes.
#[test]
fn blackout_round_commits_empty() {
    let seed = 5 + base_seed();
    let plan = scenarios::blackout(&[0, 1, 2, 3], 1, 2);
    let c = cfg(0.4, 1.0, seed, Some(plan));
    let seq = sequential(&c, &|| Box::new(Identity));
    let net = deployed_mem(&c, &|| Box::new(Identity));
    assert_matches_reference(&seq, &net);
    let r1 = &net.0.rounds[1];
    assert_eq!(r1.participants, 0);
    assert_eq!(r1.faults, K);
    // The loss column carries the previous round's value through the gap
    // (the same convention the eval columns use).
    assert_eq!(r1.train_loss.to_bits(), net.0.rounds[0].train_loss.to_bits());
    // floats_up unchanged across the empty round (cumulative counter).
    assert_eq!(net.0.rounds[0].floats_up, r1.floats_up);
    // Training resumed afterwards.
    assert_eq!(net.0.rounds[2].participants, K);
    assert!(net.0.rounds[2].floats_up > r1.floats_up);
}

/// A corrupted uplink frame is rejected by the codec and treated as
/// absence — never as a decoded update.
#[test]
fn corrupt_frame_is_rejected_not_decoded() {
    let seed = 7 + base_seed();
    let plan = scenarios::corrupt_uplink(1, 0);
    let c = cfg(0.4, 1.0, seed, Some(plan));
    let seq = sequential(&c, &|| Box::new(Identity));
    let net = deployed_mem(&c, &|| Box::new(Identity));
    assert_matches_reference(&seq, &net);
    assert_eq!(net.0.rounds[0].participants, K - 1);
    assert_eq!(net.1.worker_faults(1), 1);
}

/// The rotating-outage scenario on the threaded channel transport: every
/// engine honors the same plan identically.
#[test]
fn rolling_outage_matches_on_threaded_transport() {
    let seed = 13 + base_seed();
    let plan = scenarios::rolling_outage(K, ROUNDS);
    let c = cfg(0.5, 1.0, seed, Some(plan));
    let seq = sequential(&c, &|| Box::new(Identity));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, c.seed);
    let weights = eval.weights();
    let net = run_threaded_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, c.seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| Box::new(Identity),
        "threaded",
    )
    .unwrap();
    assert_matches_reference(&seq, &net);
    // Exactly one worker out per round.
    assert!(net.0.rounds.iter().all(|r| r.participants == K - 1 && r.faults == 1));
    assert_eq!(net.1.total_faults, ROUNDS as u64);
}

/// Flaky per-worker link profiles shape wall-clock only: a lossy fleet
/// still reproduces the clean sequential run bit-for-bit.
#[test]
fn lossy_profiles_change_timing_not_results() {
    let seed = 17 + base_seed();
    let plan = scenarios::lossy_fleet(seed, K);
    let clean = sequential(&cfg(0.4, 1.0, seed, None), &|| Box::new(Identity));
    let shaped = deployed_mem(&cfg(0.4, 1.0, seed, Some(plan)), &|| Box::new(Identity));
    assert_matches_reference(&clean, &shaped);
    assert_eq!(shaped.1.total_faults, 0);
}
