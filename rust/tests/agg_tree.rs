//! Aggregation-tree parity suite: with `cfg.shards = N` (N >= 2), the
//! sharded TCP topology — root, N mid-tier aggregators, K workers — must
//! be **bit-identical** to the in-memory engines configured with the same
//! `shards` setting: same final theta, same deterministic trace stream,
//! same modeled ledger totals (global, per worker, and per-tier
//! roll-ups), same per-round loss curves and send counts. The in-memory
//! engines mirror the tree's two-stage reduction (`shard_partial` /
//! `apply_partials` / `tree_loss_sum`) exactly, which is what makes them
//! the reference for the sharded wire path. Wire-byte columns measure
//! real frames and are excluded from cross-engine comparison, as in the
//! flat suites (`tests/net_loopback.rs`).
//!
//! Chaos coverage: a whole shard blacking out (the severed-aggregator
//! scenario modeled worker-side) replays bit-identically across engines
//! and rejoins cleanly, and a trunk that genuinely dies marks its whole
//! shard absent at the root without hanging or poisoning the run.
//!
//! The base seed honors `FL_SEED` so CI sweeps a seed matrix; set
//! `FEDRECYCLE_TRACE=1` to dump each engine's JSONL under `target/trace/`.

use std::sync::Arc;
use std::time::Duration;

use fedrecycle::compress::{Compressor, Identity};
use fedrecycle::coordinator::accounting::{CommLedger, TierMap, TierTotals};
use fedrecycle::coordinator::round::{run_fl, FlConfig, Parallelism};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::metrics::RunSeries;
use fedrecycle::net::{
    handshake_one, run_aggregator_rounds, run_mem_fl, run_sharded_root_rounds,
    run_tcp_fl, run_worker, Link, MemLink,
};
use fedrecycle::obs::{self, Encoded, Event, TraceHandle};
use fedrecycle::sim::FaultPlan;
use fedrecycle::testkit::scenarios;

const DIM: usize = 16;
const K: usize = 5;
const ROUNDS: usize = 10;
const SPREAD: f32 = 0.25;
const SIGMA: f32 = 0.03;

fn base_seed() -> u64 {
    std::env::var("FL_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn codec() -> Box<dyn Compressor> {
    Box::new(Identity)
}

/// A two-tier map splitting the fleet front/back, so the per-tier
/// roll-ups are non-trivial and must agree across engines.
fn tiers() -> Arc<TierMap> {
    Arc::new(TierMap {
        names: vec!["edge".into(), "core".into()],
        of: (0..K).map(|w| usize::from(w >= K / 2)).collect(),
    })
}

fn cfg(
    seed: u64,
    shards: usize,
    faults: Option<FaultPlan>,
    trace: TraceHandle,
) -> FlConfig {
    FlConfig {
        rounds: ROUNDS,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(0.4),
        sample_fraction: 1.0,
        eval_every: 4,
        seed,
        check_coherence: false,
        parallelism: Parallelism::Sequential,
        faults,
        tiers: Some(tiers()),
        trace: Some(trace),
        shards,
        ..Default::default()
    }
}

/// One engine's observable output: the deterministic trace stream plus
/// the run artifacts the parity contract covers.
struct RunOut {
    stream: Vec<Encoded>,
    series: RunSeries,
    ledger: CommLedger,
    theta: Vec<f32>,
}

/// Drain one engine's recorder: optionally dump the full JSONL (CI
/// failure artifact), then return the parity-checked stream.
fn stream_of(name: &str, trace: &TraceHandle) -> Vec<Encoded> {
    let rec = trace.lock().unwrap();
    assert_eq!(rec.dropped(), 0, "{name}: ring wrapped — raise the test capacity");
    if std::env::var("FEDRECYCLE_TRACE").is_ok() {
        let dir = std::path::Path::new("target").join("trace");
        obs::sink::write_jsonl(&dir.join(format!("{name}.jsonl")), &rec).unwrap();
    }
    rec.deterministic_stream()
}

/// The in-memory sequential engine at `shards = N`: `run_fl` groups the
/// reduction into the same contiguous shards and folds partials in shard
/// order, so it is the reference for the sharded wire topology.
fn engine_fl(
    name: &str,
    seed: u64,
    shards: usize,
    faults: Option<FaultPlan>,
    par: Parallelism,
) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let mut c = cfg(seed, shards, faults, Arc::clone(&trace));
    c.parallelism = par;
    let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, seed);
    let out = run_fl(&mut t, vec![0.0; DIM], &c, &|| codec(), name).unwrap();
    RunOut {
        stream: stream_of(name, &trace),
        series: out.series,
        ledger: out.ledger,
        theta: out.final_theta,
    }
}

/// The MemLink star at `shards = N` (`run_server_rounds` applies the
/// same tree mirror in-process).
fn engine_mem(name: &str, seed: u64, shards: usize, faults: Option<FaultPlan>) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let c = cfg(seed, shards, faults, Arc::clone(&trace));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (series, ledger, theta) = run_mem_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
        None,
    )
    .unwrap();
    RunOut { stream: stream_of(name, &trace), series, ledger, theta }
}

/// The real sharded topology over TCP loopback: `run_tcp_fl` delegates
/// to `run_sharded_tcp_fl` when `cfg.shards > 1` (root + N aggregator
/// threads + K stock worker clients).
fn engine_tcp(name: &str, seed: u64, shards: usize, faults: Option<FaultPlan>) -> RunOut {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let c = cfg(seed, shards, faults, Arc::clone(&trace));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (series, ledger, theta) = run_tcp_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
    )
    .unwrap();
    RunOut { stream: stream_of(name, &trace), series, ledger, theta }
}

/// Bit-diff every stream against the first, reporting the first
/// diverging event decoded rather than a wall of hex.
fn assert_streams_identical(streams: &[(&str, &[Encoded])]) {
    let (ref_name, ref_stream) = &streams[0];
    assert!(!ref_stream.is_empty(), "{ref_name}: empty deterministic stream");
    for (name, stream) in &streams[1..] {
        for (i, (a, b)) in ref_stream.iter().zip(stream.iter()).enumerate() {
            assert_eq!(
                a, b,
                "{name} diverged from {ref_name} at event {i}: {:?} vs {:?}",
                b.decode(),
                a.decode()
            );
        }
        assert_eq!(
            stream.len(),
            ref_stream.len(),
            "{name} vs {ref_name}: stream lengths differ"
        );
    }
}

/// The tier fields every engine models identically (wire bytes differ:
/// in-process engines move no frames, the sharded topology measures real
/// ones).
fn modeled(t: &TierTotals) -> (&str, u64, u64, u64, u64, u64, u64, u64) {
    (
        t.name.as_str(),
        t.workers,
        t.floats_up,
        t.bits_up,
        t.floats_down,
        t.bits_down,
        t.faults,
        t.rejoins,
    )
}

/// Everything observable except wall-clock and wire bytes must be equal
/// bit-for-bit between two engines at the same `shards` setting.
fn assert_runs_match(a: &RunOut, b: &RunOut, an: &str, bn: &str) {
    assert_streams_identical(&[(an, a.stream.as_slice()), (bn, b.stream.as_slice())]);
    assert_eq!(a.theta, b.theta, "{an} vs {bn}: final theta diverged");
    assert!(a.ledger.consistent(), "{an}: ledger inconsistent");
    assert!(b.ledger.consistent(), "{bn}: ledger inconsistent");
    assert_eq!(a.ledger.total_floats, b.ledger.total_floats, "{an} vs {bn}");
    assert_eq!(a.ledger.total_bits, b.ledger.total_bits, "{an} vs {bn}");
    assert_eq!(a.ledger.scalar_msgs, b.ledger.scalar_msgs, "{an} vs {bn}");
    assert_eq!(a.ledger.full_msgs, b.ledger.full_msgs, "{an} vs {bn}");
    assert_eq!(a.ledger.total_faults, b.ledger.total_faults, "{an} vs {bn}");
    assert_eq!(
        a.ledger.total_down_floats(),
        b.ledger.total_down_floats(),
        "{an} vs {bn}"
    );
    assert_eq!(a.ledger.total_down_bits(), b.ledger.total_down_bits(), "{an} vs {bn}");
    for w in 0..K {
        assert_eq!(
            a.ledger.worker_floats(w),
            b.ledger.worker_floats(w),
            "{an} vs {bn}: worker {w} uplink floats diverged"
        );
        assert_eq!(a.ledger.worker_bits(w), b.ledger.worker_bits(w), "worker {w}");
        assert_eq!(
            a.ledger.worker_down_floats(w),
            b.ledger.worker_down_floats(w),
            "{an} vs {bn}: worker {w} downlink floats diverged"
        );
    }
    let (ta, tb) = (a.ledger.tier_totals(), b.ledger.tier_totals());
    assert_eq!(ta.len(), tb.len(), "{an} vs {bn}: tier row counts differ");
    for (x, y) in ta.iter().zip(&tb) {
        assert_eq!(modeled(x), modeled(y), "{an} vs {bn}: tier {} diverged", x.name);
    }
    assert_eq!(a.series.rounds.len(), b.series.rounds.len(), "{an} vs {bn}");
    for (x, y) in a.series.rounds.iter().zip(&b.series.rounds) {
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{an} vs {bn}: round {} train loss diverged",
            x.round
        );
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.test_metric.to_bits(), y.test_metric.to_bits(), "round {}", x.round);
        assert_eq!(x.floats_up, y.floats_up, "round {}", x.round);
        assert_eq!(x.floats_down, y.floats_down, "round {}", x.round);
        assert_eq!(x.full_sends, y.full_sends, "round {}", x.round);
        assert_eq!(x.scalar_sends, y.scalar_sends, "round {}", x.round);
        assert_eq!(x.participants, y.participants, "round {}", x.round);
        assert_eq!(x.faults, y.faults, "round {}", x.round);
    }
}

fn count(stream: &[Encoded], pred: impl Fn(&Event) -> bool) -> usize {
    stream.iter().filter_map(Encoded::decode).filter(|e| pred(e)).count()
}

/// Clean full-participation runs at `shards` ∈ {2, 3}: the sequential
/// and scoped-thread branches of `run_fl`, the MemLink star, and the
/// real sharded TCP topology all emit one bit-identical stream with the
/// canonical per-round shape.
#[test]
fn sharded_runs_are_bit_identical_across_engines() {
    for shards in [2usize, 3] {
        let seed = 17 + base_seed();
        let runs = [
            (
                "shard_fl_seq",
                engine_fl("shard_fl_seq", seed, shards, None, Parallelism::Sequential),
            ),
            (
                "shard_fl_thr",
                engine_fl("shard_fl_thr", seed, shards, None, Parallelism::Threads(2)),
            ),
            ("shard_mem", engine_mem("shard_mem", seed, shards, None)),
            ("shard_tcp", engine_tcp("shard_tcp", seed, shards, None)),
        ];
        for (name, run) in &runs[1..] {
            assert_runs_match(&runs[0].1, run, runs[0].0, name);
        }
        let s = runs[0].1.stream.as_slice();
        assert_eq!(count(s, |e| matches!(e, Event::RoundStart { .. })), ROUNDS);
        assert_eq!(count(s, |e| matches!(e, Event::RoundCommit { .. })), ROUNDS);
        assert_eq!(count(s, |e| matches!(e, Event::BroadcastSent { .. })), K * ROUNDS);
        assert_eq!(count(s, |e| matches!(e, Event::WorkerUplink { .. })), K * ROUNDS);
        assert_eq!(count(s, |e| matches!(e, Event::FaultInjected { .. })), 0);
        // The LBGM path engaged (scalars crossed the tree) and the tier
        // roll-ups are real rows.
        assert!(runs[0].1.ledger.scalar_msgs > 0, "shards={shards}: no scalars");
        assert!(runs[3].1.ledger.wire_up_bytes > 0, "sharded TCP measured no bytes");
        assert_eq!(runs[0].1.ledger.tier_totals().len(), 2);
    }
}

/// The severed-aggregator chaos scenario, modeled worker-side: shard 1's
/// whole contiguous range goes dark for rounds 3..6 and rejoins for
/// round 6. All engines replay it bit-identically, the dark rounds
/// commit with only shard 0's workers, and full participation resumes.
#[test]
fn shard_blackout_goes_dark_and_rejoins_cleanly() {
    let shards = 2usize;
    let seed = 5 + base_seed();
    // Shard 1 of a K=5 fleet over 2 shards owns [2, 5).
    let plan = || Some(scenarios::shard_blackout(1, K, shards, 3, 6));
    let dark = K - K / shards; // 3 workers in [2, 5)
    let runs = [
        (
            "dark_fl_seq",
            engine_fl("dark_fl_seq", seed, shards, plan(), Parallelism::Sequential),
        ),
        ("dark_mem", engine_mem("dark_mem", seed, shards, plan())),
        ("dark_tcp", engine_tcp("dark_tcp", seed, shards, plan())),
    ];
    for (name, run) in &runs[1..] {
        assert_runs_match(&runs[0].1, run, runs[0].0, name);
    }
    let s = runs[0].1.stream.as_slice();
    // Swallowed broadcasts still count as sent (they die in the network).
    assert_eq!(count(s, |e| matches!(e, Event::BroadcastSent { .. })), K * ROUNDS);
    // Exactly the shard's workers miss exactly the blackout span...
    assert_eq!(
        count(s, |e| matches!(e, Event::FaultInjected { t, worker }
            if (3..6).contains(t) && *worker as usize >= K - dark)),
        3 * dark
    );
    assert_eq!(count(s, |e| matches!(e, Event::FaultInjected { .. })), 3 * dark);
    // ...every dark round commits with only shard 0's workers...
    assert_eq!(
        count(s, |e| matches!(e, Event::RoundCommit { t, participants, faults }
            if (3..6).contains(t)
                && *participants == (K - dark) as u32
                && *faults == dark as u32)),
        3
    );
    // ...and the whole fleet is back from round 6 on.
    assert_eq!(
        count(s, |e| matches!(e, Event::RoundCommit { t, participants, .. }
            if *t >= 6 && *participants == K as u32)),
        ROUNDS - 6
    );
    assert_eq!(runs[0].1.ledger.total_faults, (3 * dark) as u64);
}

/// A trunk that genuinely dies (the aggregator process is gone, not just
/// its workers): the root marks the whole shard absent every round —
/// without hanging on the dead link — commits with the surviving shard,
/// and tears down cleanly. Built by hand from MemLink trunks: shard 0 is
/// a real aggregator driving real protocol workers; shard 1's trunk peer
/// is dropped before the first round.
#[test]
fn severed_aggregator_marks_its_whole_shard_absent() {
    let shards = 2usize;
    let seed = 23 + base_seed();
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let c = cfg(seed, shards, None, Arc::clone(&trace));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    let (lo, hi) = (0usize, K / shards); // shard 0 owns [0, 2)

    // Shard 0: a real mid-tier node over MemLinks, serving real
    // `run_worker` clients through the flat handshake.
    let (root_side0, agg_side0) = MemLink::pair();
    let mut worker_handles = Vec::new();
    let mut shard_links: Vec<Box<dyn Link>> = Vec::new();
    for id in lo..hi {
        let (agg_end, wrk_end) = MemLink::pair();
        let mut wlink: Box<dyn Link> = Box::new(wrk_end);
        worker_handles.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, seed);
            run_worker(wlink.as_mut(), id, &mut t, Box::new(Identity))
        }));
        shard_links.push(Box::new(agg_end));
    }
    let agg_cfg = c.clone();
    let agg_weights = weights.clone();
    let agg = std::thread::spawn(move || -> anyhow::Result<()> {
        let mut root: Box<dyn Link> = Box::new(agg_side0);
        for (i, link) in shard_links.iter_mut().enumerate() {
            link.set_recv_timeout(Some(Duration::from_secs(30)))?;
            let w = handshake_one(link.as_mut(), K, DIM, &agg_cfg)?;
            anyhow::ensure!(w == lo + i, "link {i} handshook as worker {w}");
            link.set_recv_timeout(None)?;
        }
        run_aggregator_rounds(
            root.as_mut(),
            &mut shard_links,
            0,
            lo,
            DIM,
            &agg_weights,
            &agg_cfg,
            Duration::from_secs(60),
        )
    });

    // Shard 1: the trunk's far side is dropped — a dead aggregator.
    let (root_side1, dead_side) = MemLink::pair();
    drop(dead_side);
    let mut trunks: Vec<Box<dyn Link>> =
        vec![Box::new(root_side0), Box::new(root_side1)];
    let (series, ledger, theta) = run_sharded_root_rounds(
        &mut trunks,
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        Duration::from_secs(60),
        "dead_trunk",
    )
    .unwrap();
    agg.join().unwrap().unwrap();
    for h in worker_handles {
        h.join().unwrap().unwrap();
    }

    // Every round committed with exactly the surviving shard's workers;
    // the dead shard's are fault-counted each round.
    assert_eq!(series.rounds.len(), ROUNDS);
    for r in &series.rounds {
        assert_eq!(r.participants, hi - lo, "round {}", r.round);
        assert_eq!(r.faults, K - (hi - lo), "round {}", r.round);
    }
    assert_eq!(ledger.total_faults, (ROUNDS * (K - (hi - lo))) as u64);
    assert!(ledger.consistent());
    assert!(theta.iter().all(|x| x.is_finite()), "theta poisoned by the dead trunk");
    // The surviving shard kept training: theta moved off the origin.
    assert!(theta.iter().any(|&x| x != 0.0), "no aggregation happened");
}

/// Rerun determinism: the same seed reproduces the sharded TCP stream
/// bit-for-bit (timestamps and sequence numbers live outside the parity
/// surface).
#[test]
fn repeat_sharded_runs_reproduce_the_stream() {
    let seed = 29 + base_seed();
    let a = engine_tcp("shard_repeat_a", seed, 2, None);
    let b = engine_tcp("shard_repeat_b", seed, 2, None);
    assert_runs_match(&a, &b, "shard_repeat_a", "shard_repeat_b");
}
