//! Kernel-exactness suite: the 8-wide unrolled hot-path kernels against
//! naive reference implementations over adversarial lengths.
//!
//! Contract (documented in `linalg::vec_ops`):
//!
//! * **Elementwise kernels** (`axpy`, `scale`, `scale_add`) are
//!   **bit-identical** to the naive loop — unrolling cannot reassociate
//!   independent per-element operations.
//! * **Reductions** (`dot`, `norm2`, `projection_stats`) accumulate in 4
//!   independent f64 lanes, so they differ from the serial reference only
//!   by floating-point reassociation. The tolerance used here is the
//!   standard summation bound `n * eps * sum(|terms|)` — a documented
//!   ulp-level envelope, not a loose epsilon.
//! * **Top-K** via partial quickselect is **bit-identical** to the
//!   full-sort reference (`compress::reference_topk`): both derive the
//!   same cut magnitude and share the tie-trimming scan.
//!
//! Lengths cover the unroll boundaries demanded by ISSUE 4: 0, 1, 7, 8, 9,
//! 1023 (plus 1024/1025 for the 8-chunk edge and a couple of mid sizes).

use fedrecycle::compress::{reference_topk, Compressor, TopK};
use fedrecycle::linalg::vec_ops::{self, reference};
use fedrecycle::linalg::Workspace;
use fedrecycle::testkit::prop::{forall, Gen};
use fedrecycle::util::rng::Rng;

/// The ISSUE-mandated adversarial lengths plus 8-chunk boundary extras.
const LENGTHS: &[usize] = &[0, 1, 7, 8, 9, 15, 16, 17, 100, 1023, 1024, 1025];

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect()
}

/// Reassociation envelope for a sum of `terms` (f64): `n * eps * sum|t|`.
fn summation_bound(terms: impl Iterator<Item = f64>) -> f64 {
    let (n, mag) = terms.fold((0usize, 0f64), |(n, m), t| (n + 1, m + t.abs()));
    (n.max(1) as f64) * f64::EPSILON * mag.max(f64::MIN_POSITIVE)
}

#[test]
fn dot_within_summation_bound_of_reference() {
    for &n in LENGTHS {
        for seed in 0..5u64 {
            let a = randv(n, 1000 + seed * 31 + n as u64);
            let b = randv(n, 2000 + seed * 37 + n as u64);
            let opt = vec_ops::dot(&a, &b);
            let naive = reference::dot(&a, &b);
            let bound = summation_bound(
                a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64),
            );
            assert!(
                (opt - naive).abs() <= bound,
                "dot n={n} seed={seed}: |{opt} - {naive}| > {bound}"
            );
        }
    }
}

#[test]
fn norm2_within_summation_bound_of_reference() {
    for &n in LENGTHS {
        let a = randv(n, 3000 + n as u64);
        let opt = vec_ops::norm2(&a);
        let naive = reference::norm2(&a);
        let bound = summation_bound(a.iter().map(|x| (*x as f64) * (*x as f64)));
        assert!(
            (opt - naive).abs() <= bound,
            "norm2 n={n}: |{opt} - {naive}| > {bound}"
        );
        assert!(opt >= 0.0);
    }
}

#[test]
fn projection_stats_within_summation_bound_of_reference() {
    for &n in LENGTHS {
        let g = randv(n, 4000 + n as u64);
        let l = randv(n, 5000 + n as u64);
        let opt = vec_ops::projection_stats(&g, &l);
        let naive = reference::projection_stats(&g, &l);
        let pairs = [
            (opt.dot_gl, naive.dot_gl, "dot_gl"),
            (opt.norm2_g, naive.norm2_g, "norm2_g"),
            (opt.norm2_l, naive.norm2_l, "norm2_l"),
        ];
        let bound = summation_bound(
            g.iter()
                .zip(&l)
                .map(|(a, b)| (*a as f64).abs().max((*b as f64).abs()).powi(2)),
        );
        for (o, r, what) in pairs {
            assert!(
                (o - r).abs() <= bound,
                "projection {what} n={n}: |{o} - {r}| > {bound}"
            );
        }
        // The cached variant is exactly the fused pass minus one reduction.
        let cached = vec_ops::projection_stats_cached(&g, &l, opt.norm2_l);
        assert_eq!(cached.dot_gl.to_bits(), opt.dot_gl.to_bits());
        assert_eq!(cached.norm2_g.to_bits(), opt.norm2_g.to_bits());
    }
}

#[test]
fn elementwise_kernels_bit_identical_to_reference() {
    for &n in LENGTHS {
        let x = randv(n, 6000 + n as u64);
        let mut y_opt = randv(n, 7000 + n as u64);
        let mut y_ref = y_opt.clone();

        vec_ops::axpy(-1.7, &x, &mut y_opt);
        reference::axpy(-1.7, &x, &mut y_ref);
        assert_eq!(bits(&y_opt), bits(&y_ref), "axpy n={n}");

        vec_ops::scale_add(0.25, 3.5, &x, &mut y_opt);
        reference::scale_add(0.25, 3.5, &x, &mut y_ref);
        assert_eq!(bits(&y_opt), bits(&y_ref), "scale_add n={n}");

        vec_ops::scale(-0.6, &mut y_opt);
        reference::scale(-0.6, &mut y_ref);
        assert_eq!(bits(&y_opt), bits(&y_ref), "scale n={n}");
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn quickselect_topk_bit_identical_to_full_sort() {
    let mut ws = Workspace::new();
    // len 0 is outside TopK's domain (pinned panic in its unit tests).
    for &n in LENGTHS.iter().filter(|&&n| n > 0) {
        for fraction in [1e-9, 0.1, 0.33, 0.5, 1.0] {
            let orig = randv(n, 8000 + n as u64);
            let mut a = orig.clone();
            let mut b = orig.clone();
            let ca = TopK::new(fraction).compress(&mut a, &mut ws);
            let cb = reference_topk(&mut b, fraction);
            assert_eq!(bits(&a), bits(&b), "topk n={n} fraction={fraction}");
            assert_eq!(ca, cb, "topk cost n={n} fraction={fraction}");
        }
    }
}

#[test]
fn quickselect_topk_survives_adversarial_ties() {
    let mut ws = Workspace::new();
    // Heavy tie mass around the cut: quantized magnitudes.
    let mut r = Rng::new(42);
    for n in [7usize, 9, 64, 1023] {
        let orig: Vec<f32> = (0..n)
            .map(|_| (r.normal_f32(0.0, 1.0) * 2.0).round() * 0.5)
            .collect();
        for fraction in [0.2, 0.5] {
            let mut a = orig.clone();
            let mut b = orig.clone();
            TopK::new(fraction).compress(&mut a, &mut ws);
            reference_topk(&mut b, fraction);
            assert_eq!(bits(&a), bits(&b), "ties n={n} fraction={fraction}");
        }
    }
}

// --- randomized sweep over arbitrary lengths via the prop driver -----------

struct LenGen;

impl Gen for LenGen {
    type Value = (Vec<f32>, Vec<f32>);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let n = rng.below(600);
        let a = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        (a, b)
    }

    fn shrink(&self, (a, b): &Self::Value) -> Vec<Self::Value> {
        if a.is_empty() {
            Vec::new()
        } else {
            let h = a.len() / 2;
            vec![(a[..h].to_vec(), b[..h].to_vec())]
        }
    }
}

#[test]
fn prop_dot_and_axpy_agree_with_reference_for_any_length() {
    forall(110, 80, &LenGen, |(a, b)| {
        let opt = vec_ops::dot(a, b);
        let naive = reference::dot(a, b);
        let bound =
            summation_bound(a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64));
        if (opt - naive).abs() > bound {
            return Err(format!("dot off by {} > {bound}", (opt - naive).abs()));
        }
        let mut ya = b.clone();
        let mut yb = b.clone();
        vec_ops::axpy(0.77, a, &mut ya);
        reference::axpy(0.77, a, &mut yb);
        if ya != yb {
            return Err("axpy not bit-identical".into());
        }
        Ok(())
    });
}
