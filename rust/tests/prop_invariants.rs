//! Property tests over the DESIGN.md §6 invariants, driven by the in-tree
//! `testkit::prop` shrinkable generators (no artifacts needed — these run
//! on pure-Rust substrates and the analytic mock federation).

use fedrecycle::compress::{Compressor, ErrorFeedback, Identity, SignSgd, TopK};
use fedrecycle::coordinator::round::{run_fl, FlConfig, Parallelism, Transport};
use fedrecycle::coordinator::trainer::{LocalTrainer, MockTrainer};
use fedrecycle::coordinator::{CommLedger, Worker};
use fedrecycle::lbgm::{project, ThresholdPolicy};
use fedrecycle::linalg::vec_ops::{axpy, dot, norm2};
use fedrecycle::linalg::Workspace;
use fedrecycle::testkit::prop::{forall, Gen, PairF32, VecF32};
use fedrecycle::util::rng::Rng;

fn vec_gen(max_len: usize) -> VecF32 {
    VecF32 { min_len: 2, max_len, scale: 1.0 }
}

// --- Invariant 2: projection geometry (Def. 1) -----------------------------

#[test]
fn prop_projection_residual_orthogonal_to_lbg() {
    let gen = PairF32 { inner: vec_gen(2000) };
    forall(101, 60, &gen, |(g, l)| {
        if norm2(l) == 0.0 {
            return Ok(());
        }
        let p = project(g, Some(l));
        let mut residual = g.clone();
        axpy(-p.rho, l, &mut residual);
        let d = dot(&residual, l).abs();
        let scale = norm2(g).sqrt() * norm2(l).sqrt();
        if d <= 1e-3 * scale.max(1e-9) {
            Ok(())
        } else {
            Err(format!("residual·lbg = {d}, scale {scale}"))
        }
    });
}

#[test]
fn prop_sin2_in_unit_interval_and_def1_magnitude() {
    let gen = PairF32 { inner: vec_gen(2000) };
    forall(102, 60, &gen, |(g, l)| {
        let p = project(g, Some(l));
        if !(0.0..=1.0).contains(&p.sin2) {
            return Err(format!("sin2 = {}", p.sin2));
        }
        if norm2(l) == 0.0 {
            return Ok(());
        }
        // Def. 1: ||rho l|| = ||g|| |cos(alpha)|
        let lhs = (p.rho as f64).abs() * norm2(l).sqrt();
        let rhs = norm2(g).sqrt() * (1.0 - p.sin2).sqrt();
        if (lhs - rhs).abs() <= 1e-4 * (lhs.abs() + rhs.abs()).max(1e-9) {
            Ok(())
        } else {
            Err(format!("Def.1 magnitude: {lhs} vs {rhs}"))
        }
    });
}

// --- Invariant 6: compressor contracts -------------------------------------

#[test]
fn prop_topk_keeps_exactly_k() {
    let gen = vec_gen(3000);
    forall(103, 60, &gen, |v| {
        for fraction in [0.05, 0.25, 0.75] {
            let mut g = v.clone();
            let mut c = TopK::new(fraction);
            c.compress(&mut g, &mut Workspace::new());
            let k = ((v.len() as f64 * fraction).ceil() as usize).clamp(1, v.len());
            let nz = g.iter().filter(|x| **x != 0.0).count();
            // Zeros in the input may be "kept" as zeros: nz <= k always,
            // and nz == k when the input has >= k nonzeros.
            let input_nz = v.iter().filter(|x| **x != 0.0).count();
            if nz > k || (input_nz >= k && nz != k) {
                return Err(format!("k={k} nz={nz} input_nz={input_nz}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_error_feedback_conserves_mass() {
    // sent_t + residual_t == corrected_t == grad_t + residual_{t-1}
    let gen = vec_gen(500);
    forall(104, 40, &gen, |v| {
        let mut ef = ErrorFeedback::new(TopK::new(0.2));
        let mut residual_prev = vec![0f32; v.len()];
        let mut rng = Rng::new(7);
        for _ in 0..4 {
            let grad: Vec<f32> =
                v.iter().map(|x| x + rng.normal_f32(0.0, 0.1)).collect();
            let mut sent = grad.clone();
            ef.compress(&mut sent, &mut Workspace::new());
            for i in 0..v.len() {
                let corrected = grad[i] + residual_prev[i];
                let got = sent[i] + ef.residual()[i];
                if (got - corrected).abs() > 1e-4 * corrected.abs().max(1.0) {
                    return Err(format!(
                        "mass leak at {i}: {got} vs {corrected}"
                    ));
                }
            }
            residual_prev = ef.residual().to_vec();
        }
        Ok(())
    });
}

#[test]
fn prop_signsgd_decode_is_scaled_sign() {
    let gen = vec_gen(1000);
    forall(105, 50, &gen, |v| {
        let mut g = v.clone();
        SignSgd.compress(&mut g, &mut Workspace::new());
        let scale = g.iter().map(|x| x.abs()).fold(0f32, f32::max);
        for (o, c) in v.iter().zip(&g) {
            if c.abs() != scale && scale != 0.0 {
                return Err("non-uniform magnitude".into());
            }
            if *o > 0.0 && *c < 0.0 || *o < 0.0 && *c > 0.0 {
                return Err("sign flipped".into());
            }
        }
        Ok(())
    });
}

// --- Invariants 3 & 4: state coherence + accounting under random schedules -

struct SchedGen;

#[derive(Clone, Debug)]
struct Sched {
    workers: usize,
    rounds: usize,
    delta: f64,
    sample_fraction: f64,
    seed: u64,
}

impl Gen for SchedGen {
    type Value = Sched;

    fn generate(&self, rng: &mut Rng) -> Sched {
        Sched {
            workers: 2 + rng.below(6),
            rounds: 3 + rng.below(12),
            delta: [-1.0, 0.05, 0.3, 0.9][rng.below(4)],
            sample_fraction: [0.3, 0.6, 1.0][rng.below(3)],
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, v: &Sched) -> Vec<Sched> {
        let mut out = Vec::new();
        if v.rounds > 3 {
            out.push(Sched { rounds: v.rounds / 2, ..v.clone() });
        }
        if v.workers > 2 {
            out.push(Sched { workers: v.workers / 2, ..v.clone() });
        }
        out
    }
}

#[test]
fn prop_fl_coherence_and_accounting_under_any_schedule() {
    forall(106, 25, &SchedGen, |s| {
        let dim = 24;
        let mut trainer = MockTrainer::new(dim, s.workers, 0.2, 0.05, s.seed);
        let cfg = FlConfig {
            rounds: s.rounds,
            tau: 2,
            eta: 0.05,
            policy: ThresholdPolicy::fixed(s.delta),
            sample_fraction: s.sample_fraction,
            eval_every: 4,
            seed: s.seed,
            check_coherence: true, // asserts worker/server LBG equality
            // Exercise the threaded engine under random schedules too.
            parallelism: Parallelism::Threads(2),
            transport: Transport::Memory,
            faults: None,
            trace: None,
            wire_codec: Default::default(),
        };
        let out = run_fl(&mut trainer, vec![0.0; dim], &cfg, &|| Box::new(Identity), "p")
            .map_err(|e| format!("run failed: {e}"))?;
        if !out.ledger.consistent() {
            return Err("ledger inconsistent".into());
        }
        // Exact accounting: scalar = 1 float, full = dim floats.
        let expect =
            out.ledger.full_msgs * dim as u64 + out.ledger.scalar_msgs;
        if out.ledger.total_floats != expect {
            return Err(format!(
                "floats {} != {}",
                out.ledger.total_floats, expect
            ));
        }
        if !out.final_theta.iter().all(|x| x.is_finite()) {
            return Err("theta not finite".into());
        }
        Ok(())
    });
}

// --- Invariant 1: vanilla recovery (LBGM(delta<0) == handwritten FedAvg) ---

#[test]
fn prop_vanilla_recovery_equals_fedavg() {
    forall(107, 10, &SchedGen, |s| {
        let dim = 16;
        let cfg = FlConfig {
            rounds: s.rounds,
            tau: 2,
            eta: 0.05,
            policy: ThresholdPolicy::fixed(-1.0),
            sample_fraction: 1.0,
            eval_every: 100,
            seed: s.seed,
            check_coherence: false,
            parallelism: Parallelism::Sequential,
            transport: Transport::Memory,
            faults: None,
            trace: None,
            wire_codec: Default::default(),
        };
        let mut t1 = MockTrainer::new(dim, s.workers, 0.2, 0.05, s.seed);
        let out = run_fl(&mut t1, vec![0.0; dim], &cfg, &|| Box::new(Identity), "l")
            .map_err(|e| e.to_string())?;

        // Handwritten FedAvg on an identical trainer.
        let mut t2 = MockTrainer::new(dim, s.workers, 0.2, 0.05, s.seed);
        let w = t2.weights();
        let mut theta = vec![0f32; dim];
        for _ in 0..s.rounds {
            let mut agg = vec![0f32; dim];
            for k in 0..s.workers {
                let (_, g) = t2.local_round(k, &theta, 2, 0.05).unwrap();
                axpy(w[k], &g, &mut agg);
            }
            axpy(-0.05, &agg, &mut theta);
        }
        // The server applies per-worker updates sequentially while the
        // reference sums first — identical math, different f32 summation
        // order — so equality is up to rounding, not bit-exact (bit-exact
        // reruns of the same implementation are asserted elsewhere).
        for (a, b) in out.final_theta.iter().zip(&theta) {
            if (a - b).abs() > 1e-4 * b.abs().max(1.0) {
                return Err(format!("LBGM(delta<0) != FedAvg: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

// --- Worker-level invariant: scalar rounds never mutate the LBG ------------

#[test]
fn prop_scalar_rounds_preserve_lbg() {
    let gen = vec_gen(300);
    forall(108, 40, &gen, |v| {
        let mut w = Worker::new(0, Box::new(Identity));
        let policy = ThresholdPolicy::fixed(0.5);
        let mut rng = Rng::new(11);
        w.process_round(0, &mut v.clone(), 0.0, &policy);
        let lbg0 = w.lbg().unwrap().to_vec();
        for r in 1..5 {
            let mut jitter: Vec<f32> =
                v.iter().map(|x| x + rng.normal_f32(0.0, 0.01)).collect();
            let msg = w.process_round(r, &mut jitter, 0.0, &policy);
            if msg.is_scalar() && w.lbg().unwrap() != &lbg0[..] {
                return Err("LBG mutated on a scalar round".into());
            }
            if !msg.is_scalar() {
                return Ok(()); // refresh happened; invariant ends here
            }
        }
        Ok(())
    });
}

// --- Ledger unit property under random message streams ----------------------

#[test]
fn prop_ledger_totals_equal_per_worker_sums() {
    struct MsgsGen;
    impl Gen for MsgsGen {
        type Value = Vec<(usize, u64, bool)>;
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.below(50) + 1)
                .map(|_| (rng.below(8), rng.below(1000) as u64, rng.next_f64() < 0.5))
                .collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.len() > 1 {
                vec![v[..v.len() / 2].to_vec()]
            } else {
                Vec::new()
            }
        }
    }
    forall(109, 50, &MsgsGen, |msgs| {
        let mut l = CommLedger::new(8);
        for &(w, floats, scalar) in msgs {
            l.record(
                w,
                fedrecycle::compress::Cost { floats, bits: floats * 32 },
                scalar,
            );
        }
        if l.consistent() && l.scalar_msgs + l.full_msgs == msgs.len() as u64 {
            Ok(())
        } else {
            Err("ledger inconsistent".into())
        }
    });
}
