//! Trace-parity suite: with tracing enabled, all four round engines must
//! emit bit-identical *deterministic* event streams for the same seed +
//! config — the stream is a pure function of (seed, config, fault plan),
//! never of scheduling, transport, or wall clock. Diagnostic events
//! (deadline misses, severs, handshakes) are excluded by
//! [`fedrecycle::obs::Recorder::deterministic_stream`], which is exactly
//! the parity surface.
//!
//! The base seed honors `FL_SEED` so CI can sweep a seed matrix; set
//! `FEDRECYCLE_TRACE=1` to dump each engine's JSONL under `target/trace/`
//! (CI uploads that directory as a failure artifact).

use std::sync::Arc;

use fedrecycle::compress::{Compressor, Identity};
use fedrecycle::coordinator::round::{run_fl, FlConfig, Parallelism};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::coordinator::transport::run_threaded_fl;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::net::{run_mem_fl, run_tcp_fl};
use fedrecycle::obs::{self, Encoded, Event, TraceHandle};
use fedrecycle::sim::FaultPlan;
use fedrecycle::testkit::scenarios;

const DIM: usize = 16;
const K: usize = 4;
const ROUNDS: usize = 8;
const SPREAD: f32 = 0.25;
const SIGMA: f32 = 0.03;

fn base_seed() -> u64 {
    std::env::var("FL_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn codec() -> Box<dyn Compressor> {
    Box::new(Identity)
}

fn cfg(delta: f64, seed: u64, faults: Option<FaultPlan>, trace: TraceHandle) -> FlConfig {
    FlConfig {
        rounds: ROUNDS,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(delta),
        sample_fraction: 1.0,
        eval_every: 4,
        seed,
        check_coherence: true,
        parallelism: Parallelism::Sequential,
        faults,
        trace: Some(trace),
        ..Default::default()
    }
}

/// Drain one engine's recorder: optionally dump the full JSONL (for CI
/// artifacts), then return the parity-checked stream.
fn stream_of(name: &str, trace: &TraceHandle) -> Vec<Encoded> {
    let rec = trace.lock().unwrap();
    assert_eq!(rec.dropped(), 0, "{name}: ring wrapped — raise the test capacity");
    if std::env::var("FEDRECYCLE_TRACE").is_ok() {
        let dir = std::path::Path::new("target").join("trace");
        obs::sink::write_jsonl(&dir.join(format!("{name}.jsonl")), &rec).unwrap();
    }
    rec.deterministic_stream()
}

fn engine_fl(name: &str, delta: f64, seed: u64, faults: Option<FaultPlan>, par: Parallelism) -> Vec<Encoded> {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let mut c = cfg(delta, seed, faults, Arc::clone(&trace));
    c.parallelism = par;
    let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, seed);
    run_fl(&mut t, vec![0.0; DIM], &c, &|| codec(), name).unwrap();
    stream_of(name, &trace)
}

fn engine_threaded(name: &str, delta: f64, seed: u64, faults: Option<FaultPlan>) -> Vec<Encoded> {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let c = cfg(delta, seed, faults, Arc::clone(&trace));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    run_threaded_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
    )
    .unwrap();
    stream_of(name, &trace)
}

fn engine_mem(name: &str, delta: f64, seed: u64, faults: Option<FaultPlan>) -> Vec<Encoded> {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let c = cfg(delta, seed, faults, Arc::clone(&trace));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    run_mem_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
        None,
    )
    .unwrap();
    stream_of(name, &trace)
}

fn engine_tcp(name: &str, delta: f64, seed: u64, faults: Option<FaultPlan>) -> Vec<Encoded> {
    let trace = obs::shared(obs::recorder::DEFAULT_CAPACITY);
    let c = cfg(delta, seed, faults, Arc::clone(&trace));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, seed);
    let weights = eval.weights();
    run_tcp_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| codec(),
        name,
    )
    .unwrap();
    stream_of(name, &trace)
}

/// Bit-diff every stream against the first, reporting the first
/// diverging event (decoded, when possible) rather than a wall of hex.
fn assert_streams_identical(streams: &[(&str, Vec<Encoded>)]) {
    let (ref_name, ref_stream) = &streams[0];
    assert!(!ref_stream.is_empty(), "{ref_name}: empty deterministic stream");
    for (name, stream) in &streams[1..] {
        for (i, (a, b)) in ref_stream.iter().zip(stream.iter()).enumerate() {
            assert_eq!(
                a, b,
                "{name} diverged from {ref_name} at event {i}: {:?} vs {:?}",
                b.decode(),
                a.decode()
            );
        }
        assert_eq!(
            stream.len(),
            ref_stream.len(),
            "{name} vs {ref_name}: stream lengths differ"
        );
    }
}

fn count(stream: &[Encoded], pred: impl Fn(&Event) -> bool) -> usize {
    stream.iter().filter_map(Encoded::decode).filter(|e| pred(e)).count()
}

/// A clean full-participation run: all four engines (the sequential and
/// scoped-thread branches of `run_fl`, the mpsc star, and both net
/// drivers) emit one bit-identical stream with the canonical per-round
/// shape.
#[test]
fn clean_run_streams_are_bit_identical_across_engines() {
    let seed = 41 + base_seed();
    let d = 0.4;
    let streams = vec![
        ("clean_fl_seq", engine_fl("clean_fl_seq", d, seed, None, Parallelism::Sequential)),
        ("clean_fl_thr", engine_fl("clean_fl_thr", d, seed, None, Parallelism::Threads(2))),
        ("clean_star", engine_threaded("clean_star", d, seed, None)),
        ("clean_mem", engine_mem("clean_mem", d, seed, None)),
        ("clean_tcp", engine_tcp("clean_tcp", d, seed, None)),
    ];
    assert_streams_identical(&streams);

    let s = &streams[0].1;
    assert_eq!(count(s, |e| matches!(e, Event::RoundStart { .. })), ROUNDS);
    assert_eq!(count(s, |e| matches!(e, Event::RoundCommit { .. })), ROUNDS);
    assert_eq!(count(s, |e| matches!(e, Event::BroadcastSent { .. })), K * ROUNDS);
    assert_eq!(count(s, |e| matches!(e, Event::WorkerUplink { .. })), K * ROUNDS);
    assert_eq!(count(s, |e| matches!(e, Event::FaultInjected { .. })), 0);
    assert_eq!(count(s, |e| matches!(e, Event::Rejoin { .. })), 0);
    // Every commit reports full participation.
    assert_eq!(
        count(s, |e| matches!(e, Event::RoundCommit { participants, faults, .. }
            if *participants == K as u32 && *faults == 0)),
        ROUNDS
    );
}

/// The acceptance chaos scenario: worker 2 is severed in rounds 2–3 and
/// rejoins for round 4. On TCP the socket genuinely dies and the rejoin
/// rides the elastic accept loop; in-memory engines model the same plan
/// arithmetically — the deterministic streams must still be
/// bit-identical, with the faults and the rejoin at the same offsets.
#[test]
fn sever_and_rejoin_streams_are_bit_identical_across_engines() {
    let seed = 3 + base_seed();
    let d = 0.9;
    let plan = || Some(scenarios::disconnect_then_rejoin(2, 2, 4));
    let streams = vec![
        ("sever_fl_seq", engine_fl("sever_fl_seq", d, seed, plan(), Parallelism::Sequential)),
        ("sever_fl_thr", engine_fl("sever_fl_thr", d, seed, plan(), Parallelism::Threads(2))),
        ("sever_star", engine_threaded("sever_star", d, seed, plan())),
        ("sever_mem", engine_mem("sever_mem", d, seed, plan())),
        ("sever_tcp", engine_tcp("sever_tcp", d, seed, plan())),
    ];
    assert_streams_identical(&streams);

    let s = &streams[0].1;
    // The swallowed broadcasts still count as sent (they die in the
    // network), so the downlink shape matches the clean run.
    assert_eq!(count(s, |e| matches!(e, Event::BroadcastSent { .. })), K * ROUNDS);
    // Worker 2 misses exactly rounds 2 and 3...
    assert_eq!(
        count(s, |e| matches!(e, Event::FaultInjected { t, worker } if *worker == 2 && (*t == 2 || *t == 3))),
        2
    );
    assert_eq!(count(s, |e| matches!(e, Event::FaultInjected { .. })), 2);
    assert_eq!(count(s, |e| matches!(e, Event::WorkerUplink { .. })), K * ROUNDS - 2);
    // ...and rejoins at round 4, where its first uplink is the forced
    // dense refresh (scalar steady state everywhere else under delta=0.9
    // makes a spurious or missing refresh change the stream).
    assert_eq!(
        count(s, |e| matches!(e, Event::Rejoin { t, worker } if *t == 4 && *worker == 2)),
        1
    );
    assert_eq!(count(s, |e| matches!(e, Event::Rejoin { .. })), 1);
    assert_eq!(
        count(s, |e| matches!(e, Event::RoundCommit { t, participants, faults }
            if (*t == 2 || *t == 3) && *participants == (K - 1) as u32 && *faults == 1)),
        2
    );
}

/// Repeat runs of one engine with the same seed are bit-identical too —
/// the stream carries no run-local state (timestamps and sequence
/// numbers live outside the parity surface).
#[test]
fn repeat_runs_reproduce_the_stream() {
    let seed = 29 + base_seed();
    let a = engine_tcp("repeat_a", 0.4, seed, Some(scenarios::drop_worker(2, 2, 4)));
    let b = engine_tcp("repeat_b", 0.4, seed, Some(scenarios::drop_worker(2, 2, 4)));
    assert_streams_identical(&[("repeat_a", a), ("repeat_b", b)]);
}
