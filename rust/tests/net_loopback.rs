//! Networked-deployment parity: a TCP-loopback `serve` + K workers run
//! (and its MemLink/SimLink variants) must be **bit-identical** to the
//! sequential in-memory engine for the same seed — same final theta, same
//! ledger counters (uplink and downlink, global and per worker), same
//! per-round loss curves and send counts — across vanilla FL, standalone
//! LBGM, and sampled/plug-and-play configurations. On top of the modeled
//! counters, the networked runs must report *measured* wire bytes that
//! match the frame codec exactly.

use std::sync::Arc;
use std::time::Duration;

use fedrecycle::compress::{Compressor, Cost, Identity, TopK};
use fedrecycle::coordinator::messages::{Payload, WorkerMsg};
use fedrecycle::coordinator::round::{run_fl, FlConfig, FlOutcome, Parallelism};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::coordinator::CommLedger;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::metrics::{write_csv, RunSeries};
use fedrecycle::net::{
    accept_workers, connect_worker, run_mem_fl, run_server_rounds, run_tcp_fl, Frame,
    LinkProfile,
};

const DIM: usize = 24;
const K: usize = 5;
const SPREAD: f32 = 0.25;
const SIGMA: f32 = 0.03;

fn cfg(delta: f64, fraction: f64, seed: u64) -> FlConfig {
    FlConfig {
        rounds: 16,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(delta),
        sample_fraction: fraction,
        eval_every: 4,
        seed,
        check_coherence: false,
        parallelism: Parallelism::Sequential,
        ..Default::default()
    }
}

fn sequential(cfg: &FlConfig, codec: &dyn Fn() -> Box<dyn Compressor>) -> FlOutcome {
    let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, cfg.seed);
    run_fl(&mut t, vec![0.0; DIM], cfg, codec, "seq").unwrap()
}

fn deployed_tcp(
    cfg: &FlConfig,
    codec: &dyn Fn() -> Box<dyn Compressor>,
) -> (RunSeries, CommLedger, Vec<f32>) {
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, cfg.seed);
    let weights = eval.weights();
    run_tcp_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, cfg.seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        cfg,
        codec,
        "tcp",
    )
    .unwrap()
}

/// Everything observable except wall-clock and wire bytes must be equal
/// bit-for-bit between the sequential engine and a networked deployment.
fn assert_deployment_matches(seq: &FlOutcome, net: &(RunSeries, CommLedger, Vec<f32>)) {
    let (series, ledger, theta) = net;
    assert_eq!(&seq.final_theta, theta, "final theta diverged");
    assert_eq!(seq.ledger.total_floats, ledger.total_floats);
    assert_eq!(seq.ledger.total_bits, ledger.total_bits);
    assert_eq!(seq.ledger.scalar_msgs, ledger.scalar_msgs);
    assert_eq!(seq.ledger.full_msgs, ledger.full_msgs);
    assert_eq!(seq.ledger.total_down_floats(), ledger.total_down_floats());
    assert_eq!(seq.ledger.total_down_bits(), ledger.total_down_bits());
    assert!(ledger.consistent());
    for w in 0..K {
        assert_eq!(
            seq.ledger.worker_floats(w),
            ledger.worker_floats(w),
            "worker {w} uplink floats diverged"
        );
        assert_eq!(seq.ledger.worker_bits(w), ledger.worker_bits(w));
        assert_eq!(
            seq.ledger.worker_down_floats(w),
            ledger.worker_down_floats(w),
            "worker {w} downlink floats diverged"
        );
    }
    assert_eq!(seq.series.rounds.len(), series.rounds.len());
    for (a, b) in seq.series.rounds.iter().zip(&series.rounds) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "round {} train loss diverged",
            a.round
        );
        assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits(), "round {}", a.round);
        assert_eq!(a.test_metric.to_bits(), b.test_metric.to_bits());
        assert_eq!(a.floats_up, b.floats_up, "round {}", a.round);
        assert_eq!(a.floats_down, b.floats_down, "round {}", a.round);
        assert_eq!(a.full_sends, b.full_sends, "round {}", a.round);
        assert_eq!(a.scalar_sends, b.scalar_sends, "round {}", a.round);
    }
}

#[test]
fn tcp_loopback_matches_sequential_vanilla() {
    let c = cfg(-1.0, 1.0, 11);
    let seq = sequential(&c, &|| Box::new(Identity));
    let net = deployed_tcp(&c, &|| Box::new(Identity));
    assert_deployment_matches(&seq, &net);
    let ledger = &net.1;
    assert_eq!(ledger.scalar_msgs, 0, "vanilla FL never sends scalars");

    // Vanilla + full participation makes the measured wire bytes exactly
    // computable from the frame codec: every downlink is a Round frame of
    // DIM params, every uplink a full-grad Update of DIM floats (control
    // frames — handshake, shutdown — are not ledger-recorded).
    let round_frame = Frame::Round { t: 0, theta: vec![0.0; DIM] }.wire_bytes() as u64;
    let update_frame = Frame::Update(WorkerMsg {
        worker: 0,
        round: 0,
        payload: Payload::Full { grad: Arc::new(vec![0.0; DIM]) },
        cost: Cost { floats: DIM as u64, bits: 32 * DIM as u64 },
        train_loss: 0.0,
    })
    .wire_bytes() as u64;
    let rounds = c.rounds as u64;
    assert_eq!(ledger.wire_down_bytes, rounds * K as u64 * round_frame);
    assert_eq!(ledger.wire_up_bytes, rounds * K as u64 * update_frame);
    // The final round record snapshots the same totals (ledger == CSV).
    let last = net.0.rounds.last().unwrap();
    assert_eq!(last.wire_up_bytes, ledger.wire_up_bytes);
    assert_eq!(last.wire_down_bytes, ledger.wire_down_bytes);
}

#[test]
fn tcp_loopback_matches_sequential_lbgm() {
    let c = cfg(0.4, 1.0, 7);
    let seq = sequential(&c, &|| Box::new(Identity));
    let net = deployed_tcp(&c, &|| Box::new(Identity));
    assert_deployment_matches(&seq, &net);
    let ledger = &net.1;
    assert!(ledger.scalar_msgs > 0, "LBGM path never engaged");
    assert!(ledger.full_msgs > 0);
    assert!(ledger.wire_up_bytes > 0, "no measured uplink bytes");
    assert!(ledger.wire_down_bytes > 0, "no measured downlink bytes");
    // Scalars save real wire bytes: the uplink must be smaller than a
    // hypothetical all-full-gradient run's.
    let update_full = Frame::Update(WorkerMsg {
        worker: 0,
        round: 0,
        payload: Payload::Full { grad: Arc::new(vec![0.0; DIM]) },
        cost: Cost { floats: DIM as u64, bits: 32 * DIM as u64 },
        train_loss: 0.0,
    })
    .wire_bytes() as u64;
    assert!(ledger.wire_up_bytes < c.rounds as u64 * K as u64 * update_full);

    // The CSV output carries the measured wire bytes.
    let dir = std::env::temp_dir().join("fedrecycle_net_loopback_test");
    let path = dir.join("tcp.csv");
    write_csv(&path, std::slice::from_ref(&net.0)).unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    assert!(csv.lines().next().unwrap().contains("wire_up_bytes"));
    let last = csv.lines().last().unwrap();
    let cols: Vec<&str> = last.split(',').collect();
    // run,round,train_loss,test_loss,test_metric,floats_up,bits_up,
    // floats_down,bits_down,wire_up_bytes,wire_down_bytes,...
    assert_eq!(cols[9].parse::<u64>().unwrap(), ledger.wire_up_bytes);
    assert!(cols[10].parse::<u64>().unwrap() > 0);
}

#[test]
fn tcp_loopback_matches_sequential_sampled_topk() {
    // Client sampling + plug-and-play top-K, the hardest determinism case.
    let c = cfg(0.3, 0.6, 23);
    let codec: &dyn Fn() -> Box<dyn Compressor> = &|| Box::new(TopK::new(0.5));
    let seq = sequential(&c, codec);
    let net = deployed_tcp(&c, codec);
    assert_deployment_matches(&seq, &net);
    // Sampling: 3 of 5 workers per round.
    let r0 = &net.0.rounds[0];
    assert_eq!(r0.full_sends + r0.scalar_sends, 3);
}

#[test]
fn sharded_tcp_matches_in_memory_at_same_shards() {
    // `--shards 2`: `run_tcp_fl` delegates to the aggregation tree (root
    // + 2 mid-tier aggregators + K workers). The parity reference is the
    // in-memory engine at the *same* `shards` setting — it mirrors the
    // tree's two-stage reduction exactly (`tests/agg_tree.rs` is the full
    // suite; this pins the delegation seam in the loopback suite).
    let mut c = cfg(0.4, 1.0, 19);
    c.shards = 2;
    let seq = sequential(&c, &|| Box::new(Identity));
    let net = deployed_tcp(&c, &|| Box::new(Identity));
    assert_deployment_matches(&seq, &net);
    let ledger = &net.1;
    assert!(ledger.scalar_msgs > 0, "LBGM path never crossed the tree");
    assert!(ledger.wire_up_bytes > 0, "no measured uplink bytes");
}

#[test]
fn sim_link_straggler_run_is_bit_identical() {
    // A lossy, slow, high-latency profile changes wall-clock only: the
    // shaped MemLink deployment still reproduces the sequential run
    // bit-for-bit (SimLink models loss as deterministic retransmission).
    let c = cfg(0.4, 1.0, 31);
    let seq = sequential(&c, &|| Box::new(Identity));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, c.seed);
    let weights = eval.weights();
    let profile = LinkProfile {
        latency: std::time::Duration::from_micros(200),
        bytes_per_sec: 2_000_000,
        loss: 0.4,
        seed: 0xBEEF,
    };
    let net = run_mem_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, c.seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| Box::new(Identity),
        "sim",
        Some(profile),
    )
    .unwrap();
    assert_deployment_matches(&seq, &net);
    assert!(net.1.wire_up_bytes > 0);
}

#[test]
fn rogue_connection_does_not_kill_the_server() {
    // A port-scanner-ish peer connects and sends garbage; the server must
    // reject it and still complete a bit-identical run with the real
    // workers.
    let c = cfg(0.5, 1.0, 13);
    let seq = sequential(&c, &|| Box::new(Identity));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let rogue = std::thread::spawn(move || {
        use std::io::Write;
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        let _ = s.write_all(b"GET / HTTP/1.1\r\n\r\n");
    });
    let mut handles = Vec::new();
    for id in 0..K {
        handles.push(std::thread::spawn(move || {
            let mut t = MockTrainer::new(DIM, K, SPREAD, SIGMA, 13);
            connect_worker(addr, id, &mut t, Box::new(Identity))
        }));
    }
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, c.seed);
    let weights = eval.weights();
    let mut links =
        accept_workers(&listener, K, DIM, &c, Duration::from_secs(20)).unwrap();
    let net = run_server_rounds(
        &mut links,
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        Duration::from_secs(60),
        "rogue",
    )
    .unwrap();
    rogue.join().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_deployment_matches(&seq, &net);
}

#[test]
fn mem_link_deployment_matches_sequential() {
    let c = cfg(0.5, 1.0, 3);
    let seq = sequential(&c, &|| Box::new(Identity));
    let mut eval = MockTrainer::new(DIM, K, SPREAD, 0.0, c.seed);
    let weights = eval.weights();
    let net = run_mem_fl(
        |_id| MockTrainer::new(DIM, K, SPREAD, SIGMA, c.seed),
        &mut eval,
        vec![0.0; DIM],
        weights,
        &c,
        &|| Box::new(Identity),
        "mem",
        None,
    )
    .unwrap();
    assert_deployment_matches(&seq, &net);
}
