//! Golden-trace regression fixture: a small seeded LBGM run's CSV is
//! committed under `tests/golden/`, and every test run regenerates the
//! trace and diffs it byte-for-byte. Any change that affects convergence,
//! accounting, sampling, or CSV schema fails loudly here — if the change
//! is deliberate, regenerate the fixture (run this test, then copy
//! `target/golden-diff/lbgm_small.fresh.csv` over the committed file) and
//! say so in the commit.
//!
//! `wall_secs` and the four `t_*` phase-timing columns are zeroed before
//! the diff (the only nondeterministic, wall-clock-derived columns);
//! everything else in the engine is bit-reproducible per seed.

use fedrecycle::compress::Identity;
use fedrecycle::coordinator::round::{run_fl, FlConfig, Parallelism};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::metrics::write_csv;

const GOLDEN: &str = include_str!("golden/lbgm_small.csv");

#[test]
fn lbgm_small_run_matches_golden_trace() {
    let cfg = FlConfig {
        rounds: 12,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(0.05),
        sample_fraction: 1.0,
        eval_every: 3,
        seed: 5,
        check_coherence: true,
        parallelism: Parallelism::Sequential,
        ..Default::default()
    };
    let mut trainer = MockTrainer::new(16, 4, 0.25, 0.02, cfg.seed);
    let mut out =
        run_fl(&mut trainer, vec![0.0; 16], &cfg, &|| Box::new(Identity), "golden")
            .expect("golden run failed");
    for r in &mut out.series.rounds {
        r.wall_secs = 0.0;
        r.t_train = 0.0;
        r.t_compress = 0.0;
        r.t_comm = 0.0;
        r.t_aggregate = 0.0;
    }
    let dir = std::env::temp_dir().join("fedrecycle_golden_trace");
    let path = dir.join("fresh.csv");
    write_csv(&path, std::slice::from_ref(&out.series)).unwrap();
    let fresh = std::fs::read_to_string(&path).unwrap();

    if fresh != GOLDEN {
        // Persist both sides where CI uploads them as a failure artifact.
        let diff_dir = std::path::Path::new("target").join("golden-diff");
        std::fs::create_dir_all(&diff_dir).ok();
        std::fs::write(diff_dir.join("lbgm_small.fresh.csv"), &fresh).ok();
        std::fs::write(diff_dir.join("lbgm_small.golden.csv"), GOLDEN).ok();
        let first_diff = fresh
            .lines()
            .zip(GOLDEN.lines())
            .enumerate()
            .find(|(_, (a, b))| a != b)
            .map(|(i, (a, b))| format!("line {}:\n  fresh:  {a}\n  golden: {b}", i + 1))
            .unwrap_or_else(|| {
                format!(
                    "line counts differ: fresh {} vs golden {}",
                    fresh.lines().count(),
                    GOLDEN.lines().count()
                )
            });
        panic!(
            "golden LBGM trace diverged (convergence-affecting change?).\n{first_diff}\n\
             Both traces written to target/golden-diff/ — regenerate the fixture \
             only if the change is intentional."
        );
    }
}
