//! End-to-end federated training through the real PJRT artifacts:
//! LBGM vs vanilla, plug-and-play codecs, client sampling, and the
//! bit-exact vanilla-recovery invariant (requires `make artifacts`).

use fedrecycle::config::{CodecKind, ExperimentConfig};
use fedrecycle::figures::common::run_arm;
use fedrecycle::runtime::{Manifest, Runtime};

fn env() -> Option<(Runtime, Manifest)> {
    let m = Manifest::load(&Manifest::default_dir()).ok()?;
    let rt = Runtime::cpu().ok()?;
    Some((rt, m))
}

macro_rules! require_env {
    ($rt:ident, $m:ident) => {
        let Some(($rt, $m)) = env() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
    };
}

fn small_cfg(delta: f64) -> ExperimentConfig {
    ExperimentConfig {
        variant: "fcn_mnist".into(),
        dataset: "synth_mnist".into(),
        workers: 5,
        rounds: 8,
        tau: 2,
        eta: 0.05,
        delta,
        noniid: true,
        labels_per_worker: 3,
        train_n: 400,
        test_n: 64,
        eval_every: 2,
        seed: 5,
        ..Default::default()
    }
}

#[test]
fn vanilla_fl_learns_on_pjrt() {
    require_env!(rt, m);
    let out = run_arm(&rt, &m, &small_cfg(-1.0), "vanilla").unwrap();
    let first = out.series.rounds[0].train_loss;
    let last = out.series.last().unwrap().train_loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert_eq!(out.ledger.scalar_msgs, 0);
    assert!(out.ledger.consistent());
    // Every message is a full gradient of M floats.
    let m_dim = m.variant("fcn_mnist").unwrap().param_count as u64;
    assert_eq!(out.ledger.total_floats, out.ledger.full_msgs * m_dim);
}

#[test]
fn lbgm_saves_floats_on_pjrt() {
    require_env!(rt, m);
    let vanilla = run_arm(&rt, &m, &small_cfg(-1.0), "vanilla").unwrap();
    let lbgm = run_arm(&rt, &m, &small_cfg(0.3), "lbgm").unwrap();
    assert!(lbgm.ledger.scalar_msgs > 0, "no scalar uplinks at delta=0.3");
    assert!(
        lbgm.ledger.total_floats < vanilla.ledger.total_floats,
        "LBGM should reduce floats"
    );
    // Learning still happens.
    let last = lbgm.series.last().unwrap().train_loss;
    assert!(last < lbgm.series.rounds[0].train_loss);
}

#[test]
fn vanilla_recovery_bit_exact_on_pjrt() {
    require_env!(rt, m);
    // Same seed, delta<0 twice: identical final parameters (Takeaway 1 +
    // determinism of the whole stack).
    let a = run_arm(&rt, &m, &small_cfg(-1.0), "a").unwrap();
    let b = run_arm(&rt, &m, &small_cfg(-1.0), "b").unwrap();
    assert_eq!(a.final_theta, b.final_theta);
}

#[test]
fn plug_and_play_codecs_run_on_pjrt() {
    require_env!(rt, m);
    for codec in [
        CodecKind::TopKEf { fraction: 0.1 },
        CodecKind::Atomo { rank: 2 },
        CodecKind::SignSgd,
    ] {
        let mut cfg = small_cfg(0.3);
        cfg.rounds = 4;
        cfg.codec = codec;
        let out = run_arm(&rt, &m, &cfg, "pnp").unwrap();
        assert!(out.ledger.consistent());
        assert!(out.series.last().unwrap().train_loss.is_finite());
        // Compressed full messages must be cheaper than dense.
        let m_dim = m.variant("fcn_mnist").unwrap().param_count as u64;
        if out.ledger.full_msgs > 0 {
            assert!(
                out.ledger.total_floats < out.ledger.full_msgs * m_dim,
                "{codec:?} did not compress"
            );
        }
    }
}

#[test]
fn client_sampling_on_pjrt() {
    require_env!(rt, m);
    let mut cfg = small_cfg(0.3);
    cfg.sample_fraction = 0.4; // 2 of 5 workers per round
    let out = run_arm(&rt, &m, &cfg, "sampled").unwrap();
    for r in &out.series.rounds {
        assert_eq!(r.full_sends + r.scalar_sends, 2);
    }
    assert!(out.series.last().unwrap().train_loss.is_finite());
}

#[test]
fn regression_federation_on_pjrt() {
    require_env!(rt, m);
    let cfg = ExperimentConfig {
        variant: "cnn_celeba".into(),
        dataset: "synth_celeba".into(),
        workers: 4,
        rounds: 5,
        tau: 1,
        eta: 0.05,
        delta: 0.3,
        noniid: false,
        train_n: 256,
        test_n: 64,
        eval_every: 2,
        seed: 6,
        ..Default::default()
    };
    let out = run_arm(&rt, &m, &cfg, "reg").unwrap();
    let first = out.series.rounds[0].train_loss;
    let last = out.series.last().unwrap().train_loss;
    assert!(last < first, "regression loss did not decrease");
}

#[test]
fn lm_federation_on_pjrt() {
    require_env!(rt, m);
    let cfg = ExperimentConfig {
        variant: "transformer_lm".into(),
        dataset: "corpus".into(),
        workers: 3,
        rounds: 4,
        tau: 1,
        eta: 0.1,
        delta: 0.3,
        train_n: 300, // unused for corpus (validation floor only)
        seed: 7,
        eval_every: 2,
        ..Default::default()
    };
    let out = run_arm(&rt, &m, &cfg, "lm").unwrap();
    // Starting loss ~ ln(64) + init transient; must be sane and shrinking.
    let first = out.series.rounds[0].train_loss;
    let last = out.series.last().unwrap().train_loss;
    assert!(first < 6.5 && first > 3.0, "lm start loss {first}");
    assert!(last <= first + 0.1, "lm loss exploded: {first} -> {last}");
}
