//! Regression: the threaded round engine must be **bit-identical** to the
//! sequential engine for a fixed seed — same `final_theta`, same
//! `CommLedger` totals (global and per worker), same per-round scalar/full
//! send counts, same loss curves — across vanilla (`delta < 0`), standalone
//! LBGM, client sampling, and plug-and-play (top-K codec) configurations.
//!
//! This is the contract that lets every harness default to
//! `Parallelism::Threads(0)`: the knob changes wall-clock only, never
//! results.

use fedrecycle::compress::{Compressor, Identity, TopK};
use fedrecycle::coordinator::round::{run_fl, FlConfig, FlOutcome, Parallelism, Transport};
use fedrecycle::coordinator::trainer::MockTrainer;
use fedrecycle::lbgm::ThresholdPolicy;

const DIM: usize = 48;
const WORKERS: usize = 8;

fn outcome(
    base: &FlConfig,
    par: Parallelism,
    codec: &dyn Fn() -> Box<dyn Compressor>,
) -> FlOutcome {
    let cfg = FlConfig { parallelism: par, ..base.clone() };
    let mut t = MockTrainer::new(DIM, WORKERS, 0.25, 0.05, cfg.seed);
    run_fl(&mut t, vec![0.0; DIM], &cfg, codec, "parity").unwrap()
}

/// Run `base` sequentially and under several thread counts and assert
/// everything observable is equal bit-for-bit.
fn assert_parity(base: FlConfig, codec: &dyn Fn() -> Box<dyn Compressor>) {
    let seq = outcome(&base, Parallelism::Sequential, codec);
    for par in [
        Parallelism::Threads(2),
        Parallelism::Threads(4),
        Parallelism::Threads(0), // auto: one thread per core
    ] {
        let thr = outcome(&base, par, codec);
        assert_eq!(
            seq.final_theta, thr.final_theta,
            "final_theta diverged under {par:?}"
        );
        assert_eq!(seq.ledger.total_floats, thr.ledger.total_floats);
        assert_eq!(seq.ledger.total_bits, thr.ledger.total_bits);
        assert_eq!(seq.ledger.scalar_msgs, thr.ledger.scalar_msgs);
        assert_eq!(seq.ledger.full_msgs, thr.ledger.full_msgs);
        assert_eq!(seq.ledger.total_down_floats(), thr.ledger.total_down_floats());
        assert_eq!(seq.ledger.total_down_bits(), thr.ledger.total_down_bits());
        assert!(thr.ledger.consistent());
        for w in 0..WORKERS {
            assert_eq!(
                seq.ledger.worker_floats(w),
                thr.ledger.worker_floats(w),
                "worker {w} floats diverged under {par:?}"
            );
            assert_eq!(seq.ledger.worker_bits(w), thr.ledger.worker_bits(w));
        }
        assert_eq!(seq.series.rounds.len(), thr.series.rounds.len());
        for (a, b) in seq.series.rounds.iter().zip(&thr.series.rounds) {
            assert_eq!(a.full_sends, b.full_sends, "round {}", a.round);
            assert_eq!(a.scalar_sends, b.scalar_sends, "round {}", a.round);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "train loss diverged at round {}",
                a.round
            );
            assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
            assert_eq!(a.test_metric.to_bits(), b.test_metric.to_bits());
            assert_eq!(a.floats_up, b.floats_up);
            assert_eq!(a.bits_up, b.bits_up);
        }
    }
}

fn base_cfg(delta: f64, seed: u64) -> FlConfig {
    FlConfig {
        rounds: 30,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(delta),
        sample_fraction: 1.0,
        eval_every: 5,
        seed,
        check_coherence: true,
        parallelism: Parallelism::Sequential,
        transport: Transport::Memory,
        faults: None,
        trace: None,
        wire_codec: Default::default(),
    }
}

#[test]
fn parity_vanilla() {
    // delta < 0: every round full-sends (exact FedAvg recovery path).
    assert_parity(base_cfg(-1.0, 11), &|| Box::new(Identity));
}

#[test]
fn parity_lbgm() {
    let cfg = base_cfg(0.3, 12);
    assert_parity(cfg, &|| Box::new(Identity));
}

#[test]
fn parity_sampled() {
    let cfg = FlConfig { sample_fraction: 0.5, ..base_cfg(0.3, 13) };
    assert_parity(cfg, &|| Box::new(Identity));
}

#[test]
fn parity_plug_and_play_topk() {
    let cfg = base_cfg(0.5, 14);
    assert_parity(cfg, &|| Box::new(TopK::new(0.25)));
}

#[test]
fn parity_adaptive_policy() {
    // The Theorem-1 adaptive policy exercises grad_norm2 in the decision.
    let cfg = FlConfig {
        policy: ThresholdPolicy::AdaptiveDelta2 { delta2: 0.05, tau: 2 },
        ..base_cfg(0.0, 15)
    };
    assert_parity(cfg, &|| Box::new(Identity));
}

#[test]
fn lbgm_actually_engages_in_parity_runs() {
    // Guard against the parity suite silently degenerating to all-full
    // sends (which would make parity trivially true).
    let out = outcome(&base_cfg(0.3, 12), Parallelism::Threads(2), &|| {
        Box::new(Identity)
    });
    assert!(out.ledger.scalar_msgs > 0, "no scalar uplinks at delta=0.3");
    assert!(out.ledger.full_msgs > 0);
}
