//! Tier-1 `fedlint` gate: the committed tree must lint clean, every
//! annotation must be load-bearing, and each rule family must fire on a
//! seeded fixture (and stay quiet on its annotated twin).
//!
//! The committed-tree test is the actual enforcement point: it walks
//! `rust/src`, `benches`, and `examples` exactly like the
//! `fedrecycle lint` subcommand and fails the suite on any violation —
//! including an annotation whose hit has since been fixed (unused
//! allows are violations, so exceptions cannot go stale).

use std::path::Path;

use fedrecycle::lint::rules::{
    ALLOC_DISCIPLINE, ANNOTATION, DETERMINISM, PANIC_FREEDOM, REDUCTION_ORDER, UNSAFE_CODE,
};
use fedrecycle::lint::{annot, lexer, lint_source, run_tree, walker};

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

// ---------------------------------------------------------------------------
// The tree itself
// ---------------------------------------------------------------------------

#[test]
fn committed_tree_is_lint_clean() {
    let report = run_tree(repo_root()).expect("walk the repo");
    assert!(
        report.files_scanned > 40,
        "suspiciously small walk ({} files) — wrong root?",
        report.files_scanned
    );
    assert!(
        report.allows_honored >= 25,
        "annotation inventory shrank to {} — did a scope or rule get disabled?",
        report.allows_honored
    );
    assert!(report.is_clean(), "fedlint violations in the tree:\n{}", report.render());
}

/// Deleting any single `lint: allow` from the tree must resurface at
/// least one violation — an annotation that suppresses nothing is dead
/// weight and the unused-allow rule would flag it, so this holds by
/// construction; here we prove it hit by hit.
#[test]
fn every_annotation_is_load_bearing() {
    let files = walker::walk(repo_root()).expect("walk the repo");
    let mut checked = 0usize;
    for f in &files {
        let lines = lexer::strip(&f.text);
        let (allows, errors) = annot::collect(&lines);
        assert!(errors.is_empty(), "{}: malformed annotation: {errors:?}", f.rel_path);
        for a in &allows {
            let mutated = f
                .text
                .lines()
                .enumerate()
                .map(|(i, l)| {
                    if i + 1 == a.line {
                        l.find("// lint:").map_or(l, |p| &l[..p])
                    } else {
                        l
                    }
                })
                .collect::<Vec<_>>()
                .join("\n");
            let v = lint_source(&f.rel_path, &mutated);
            assert!(
                !v.is_empty(),
                "{}:{}: removing allow({}) changes nothing — stale annotation",
                f.rel_path,
                a.line,
                a.rule
            );
            checked += 1;
        }
    }
    assert!(checked >= 25, "expected a substantial annotation inventory, found {checked}");
}

/// Re-introducing a violation into a committed, clean file fails the
/// pass (the acceptance check the CI lint job rides on).
#[test]
fn seeded_violation_in_committed_file_fails() {
    let wire = repo_root().join("rust/src/net/wire.rs");
    let mut text = std::fs::read_to_string(wire).expect("read wire.rs");
    assert!(lint_source("rust/src/net/wire.rs", &text).is_empty());
    text.push_str("\nfn seeded_regression(buf: &[u8]) -> u8 {\n    buf[0]\n}\n");
    let v = lint_source("rust/src/net/wire.rs", &text);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, PANIC_FREEDOM);
}

// ---------------------------------------------------------------------------
// Per-rule fixtures: each family fires, and its annotated twin is quiet
// ---------------------------------------------------------------------------

#[test]
fn determinism_fixture_and_annotated_twin() {
    let bad = "use std::collections::HashMap;\nlet t0 = std::time::Instant::now();\n";
    let v = lint_source("rust/src/coordinator/round.rs", bad);
    assert_eq!(v.len(), 2, "{v:?}");
    assert!(v.iter().all(|x| x.rule == DETERMINISM));
    let twin = "\
use std::collections::HashMap; // lint: allow(determinism, \"never iterated\")
// lint: allow(determinism, \"wall-clock metric only\")
let t0 = std::time::Instant::now();
";
    assert!(lint_source("rust/src/coordinator/round.rs", twin).is_empty());
}

#[test]
fn reduction_fixture_and_annotated_twin() {
    let bad = "let s: f32 = xs.iter().sum();\nloss_sum += x as f64;\n";
    let v = lint_source("rust/src/lbgm/scalar.rs", bad);
    assert_eq!(v.len(), 2, "{v:?}");
    assert!(v.iter().all(|x| x.rule == REDUCTION_ORDER));
    let twin = "\
// lint: allow(reduction_order, \"fixed slice order\")
let s: f32 = xs.iter().sum();
loss_sum += x as f64; // lint: allow(reduction_order, \"fixed step order\")
";
    assert!(lint_source("rust/src/lbgm/scalar.rs", twin).is_empty());
    // Integer reductions need no annotation at all.
    let ints = "let n: usize = xs.iter().map(f).sum();\ncount += 1;\n";
    assert!(lint_source("rust/src/lbgm/scalar.rs", ints).is_empty());
}

#[test]
fn panic_fixture_and_annotated_twin() {
    let bad = "let b = buf[0].unwrap();\nassert!(ok);\n";
    let v = lint_source("rust/src/net/client.rs", bad);
    assert_eq!(v.len(), 3, "{v:?}"); // indexing + unwrap + assert
    assert!(v.iter().all(|x| x.rule == PANIC_FREEDOM));
    let twin = "\
// lint: allow(panic_freedom, \"index and option both length-checked by caller\")
let b = buf[0].unwrap();
";
    assert!(lint_source("rust/src/net/client.rs", twin).is_empty());
    // The same source outside the frame-handling scope is legal.
    assert!(lint_source("rust/src/figures/common.rs", bad).is_empty());
}

#[test]
fn alloc_fixture_and_annotated_twin() {
    let bad = "let v = grad.to_vec();\n";
    let v = lint_source("rust/src/compress/topk.rs", bad);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, ALLOC_DISCIPLINE);
    let twin = "let v = grad.to_vec(); // lint: allow(alloc_discipline, \"cold refresh path\")\n";
    assert!(lint_source("rust/src/compress/topk.rs", twin).is_empty());
}

#[test]
fn unsafe_fixture_fires_even_in_test_regions() {
    let word = ["un", "safe"].concat(); // keep the token out of this file
    let bad = format!("#[cfg(test)]\nmod tests {{\n    {word} fn t() {{}}\n}}\n");
    let v = lint_source("examples/quickstart.rs", &bad);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, UNSAFE_CODE);
    let twin = format!("// lint: allow(unsafe_code, \"fixture twin\")\n{word} fn t() {{}}\n");
    assert!(lint_source("examples/quickstart.rs", &twin).is_empty());
}

// ---------------------------------------------------------------------------
// Annotation hygiene
// ---------------------------------------------------------------------------

#[test]
fn unused_allow_is_a_violation() {
    let src = "clean_code(); // lint: allow(determinism, \"suppresses nothing\")\n";
    let v = lint_source("rust/src/coordinator/round.rs", src);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, ANNOTATION);
    assert!(v[0].message.contains("unused"));
}

#[test]
fn malformed_annotations_are_violations() {
    for src in [
        "x(); // lint: allow(determinism)\n",         // no reason
        "x(); // lint: allow(determinism, \" \")\n",  // empty reason
        "x(); // lint: allow(speling, \"oops\")\n",   // unknown rule
        "x(); // lint: allow(determinism, \"r\") y\n", // trailing garbage
        "x(); // lint: deny(determinism)\n",          // unknown verb
    ] {
        let v = lint_source("rust/src/coordinator/round.rs", src);
        assert_eq!(v.len(), 1, "{src:?} -> {v:?}");
        assert_eq!(v[0].rule, ANNOTATION, "{src:?}");
    }
}

#[test]
fn report_renders_counts_and_locations() {
    let report = run_tree(repo_root()).expect("walk the repo");
    let rendered = report.render();
    assert!(rendered.contains("file(s) scanned"), "{rendered}");
    assert!(rendered.contains("allow(s) honored"), "{rendered}");
}
