//! Networked deployment demo: the full client/server FL protocol over TCP
//! loopback sockets in one process — versioned wire codec, handshake,
//! per-round theta broadcast, deadline-collected uplinks — compared
//! against the sequential in-memory engine to show the results are
//! bit-identical while the ledger now reports *measured* wire bytes.
//! A second pass runs the same deployment over SimLink-shaped links
//! (straggler profile: high latency, thin uplink, 30% loss) to show that
//! shaping changes wall-clock only.
//!
//!     cargo run --release --example net_deployment -- --workers 6

use std::time::{Duration, Instant};

use fedrecycle::compress::Identity;
use fedrecycle::coordinator::round::{run_fl, FlConfig};
use fedrecycle::coordinator::trainer::{LocalTrainer, MockTrainer};
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::net::{run_mem_fl, run_tcp_fl, LinkProfile};
use fedrecycle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let k = args.usize_or("workers", 6);
    let dim = args.usize_or("dim", 128);
    let rounds = args.usize_or("rounds", 30);
    let seed = args.u64_or("seed", 9);

    let cfg = FlConfig {
        rounds,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(0.3),
        eval_every: 5,
        seed,
        ..Default::default()
    };
    let spread = 0.3f32;
    let sigma = 0.02f32;

    // Reference: the sequential in-memory engine.
    let mut seq_trainer = MockTrainer::new(dim, k, spread, sigma, seed);
    let seq = run_fl(
        &mut seq_trainer,
        vec![0.0; dim],
        &FlConfig { parallelism: fedrecycle::coordinator::Parallelism::Sequential, ..cfg.clone() },
        &|| Box::new(Identity),
        "sequential",
    )?;

    // The same run as a real client/server deployment over TCP loopback.
    let mut eval = MockTrainer::new(dim, k, spread, 0.0, seed);
    let weights = eval.weights();
    let t0 = Instant::now();
    let (series, ledger, theta) = run_tcp_fl(
        |_id| MockTrainer::new(dim, k, spread, sigma, seed),
        &mut eval,
        vec![0.0; dim],
        weights,
        &cfg,
        &|| Box::new(Identity),
        "tcp",
    )?;
    let tcp_secs = t0.elapsed().as_secs_f64();

    println!("TCP star deployment, K={k} workers, dim={dim}, {rounds} rounds:");
    println!(
        "  bit-identical to sequential engine: {}",
        if theta == seq.final_theta { "yes" } else { "NO (bug!)" }
    );
    println!(
        "  modeled:  {} floats up / {} floats down",
        ledger.total_floats,
        ledger.total_down_floats()
    );
    println!(
        "  measured: {} bytes up / {} bytes down on the wire ({:.1}% scalar uplinks)",
        ledger.wire_up_bytes,
        ledger.wire_down_bytes,
        100.0 * series.scalar_fraction()
    );
    println!("  wall-clock: {tcp_secs:.3}s");

    // Straggler scenario: every worker uplink shaped to 200us latency,
    // 1 MB/s, 30% loss (deterministic retransmission model).
    let profile = LinkProfile {
        latency: Duration::from_micros(200),
        bytes_per_sec: 1_000_000,
        loss: 0.3,
        seed,
    };
    let mut eval2 = MockTrainer::new(dim, k, spread, 0.0, seed);
    let weights2 = eval2.weights();
    let t1 = Instant::now();
    let (_, _, theta_sim) = run_mem_fl(
        |_id| MockTrainer::new(dim, k, spread, sigma, seed),
        &mut eval2,
        vec![0.0; dim],
        weights2,
        &cfg,
        &|| Box::new(Identity),
        "straggler",
        Some(profile),
    )?;
    println!(
        "straggler-shaped links: {:.3}s wall-clock, results still identical: {}",
        t1.elapsed().as_secs_f64(),
        if theta_sim == seq.final_theta { "yes" } else { "NO (bug!)" }
    );
    Ok(())
}
