//! Fig. 5-style standalone-LBGM experiment with full CLI control.
//!
//!     cargo run --release --example fl_noniid -- \
//!         --dataset synth_cifar --variant cnn_cifar --delta 0.5 --rounds 30 \
//!         --parallelism auto
//!
//! Runs vanilla + LBGM arms on a non-iid federation and writes the round
//! curves to results/fl_noniid.csv. `--parallelism seq|auto|<threads>`
//! selects the round engine (results are bit-identical across settings).

use std::path::Path;

use fedrecycle::config::ExperimentConfig;
use fedrecycle::coordinator::Parallelism;
use fedrecycle::figures::common::run_arm;
use fedrecycle::metrics::write_csv;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;

    let base = ExperimentConfig {
        variant: args.get_or("variant", "cnn_mnist"),
        dataset: args.get_or("dataset", "synth_mnist"),
        workers: args.usize_or("workers", 10),
        rounds: args.usize_or("rounds", 30),
        tau: args.usize_or("tau", 2),
        eta: args.f64_or("eta", 0.05),
        noniid: true,
        labels_per_worker: args.usize_or("labels-per-worker", 3),
        train_n: args.usize_or("train-n", 1500),
        test_n: args.usize_or("test-n", 256),
        eval_every: 3,
        seed: args.u64_or("seed", 2),
        parallelism: Parallelism::parse(&args.get_or("parallelism", "auto"))?,
        ..Default::default()
    };
    let delta = args.f64_or("delta", 0.2);

    let vanilla = run_arm(&rt, &manifest, &ExperimentConfig { delta: -1.0, ..base.clone() }, "vanilla")?;
    let lbgm = run_arm(
        &rt,
        &manifest,
        &ExperimentConfig { delta, ..base.clone() },
        &format!("lbgm_d{delta}"),
    )?;

    println!(
        "\n{} on {} (non-iid, K={}):",
        base.variant, base.dataset, base.workers
    );
    println!(
        "  vanilla: metric {:.4}, {} floats",
        vanilla.series.final_metric(),
        vanilla.ledger.total_floats
    );
    println!(
        "  lbgm(d={delta}): metric {:.4}, {} floats ({:.1}% saving, {:.1}% scalar rounds)",
        lbgm.series.final_metric(),
        lbgm.ledger.total_floats,
        100.0 * lbgm.series.savings_vs(vanilla.ledger.total_floats),
        100.0 * lbgm.series.scalar_fraction()
    );
    write_csv(
        Path::new("results/fl_noniid.csv").as_ref(),
        &[vanilla.series, lbgm.series],
    )?;
    println!("curves written to results/fl_noniid.csv");
    Ok(())
}
