//! Quickstart: LBGM vs vanilla FL on a small non-iid federation.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Trains the FCN classifier over 5 workers of the synthetic MNIST
//! analogue twice — once with vanilla FedAvg, once with LBGM (delta=0.3) —
//! and prints the accuracy and communication comparison.

use fedrecycle::config::ExperimentConfig;
use fedrecycle::figures::common::run_arm;
use fedrecycle::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;

    let base = ExperimentConfig {
        variant: "fcn_mnist".into(),
        dataset: "synth_mnist".into(),
        workers: 5,
        rounds: 15,
        tau: 2,
        eta: 0.05,
        noniid: true,
        labels_per_worker: 3,
        train_n: 600,
        test_n: 128,
        eval_every: 3,
        seed: 1,
        ..Default::default()
    };

    println!("running vanilla FL (delta < 0: every round sends the full gradient)...");
    let vanilla = run_arm(&rt, &manifest, &ExperimentConfig { delta: -1.0, ..base.clone() }, "vanilla")?;

    println!("running LBGM (delta = 0.3: scalar LBC when sin^2(alpha) <= 0.3)...");
    let lbgm = run_arm(&rt, &manifest, &ExperimentConfig { delta: 0.3, ..base }, "lbgm")?;

    println!();
    println!("{:<10} {:>10} {:>16} {:>14}", "run", "accuracy", "floats uplinked", "scalar msgs");
    for (name, out) in [("vanilla", &vanilla), ("lbgm", &lbgm)] {
        println!(
            "{:<10} {:>9.1}% {:>16} {:>13.1}%",
            name,
            100.0 * out.series.final_metric(),
            out.ledger.total_floats,
            100.0 * out.series.scalar_fraction()
        );
    }
    println!(
        "\ncommunication saving: {:.1}% (paper Fig. 5 reports savings on the order of 10^7 floats/worker)",
        100.0 * lbgm.series.savings_vs(vanilla.ledger.total_floats)
    );
    Ok(())
}
