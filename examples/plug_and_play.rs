//! Plug-and-play demo (paper P3/P4): stack LBGM on top of top-K+EF, ATOMO
//! and SignSGD and compare against each codec alone.
//!
//!     cargo run --release --example plug_and_play -- --rounds 20

use fedrecycle::config::{CodecKind, ExperimentConfig};
use fedrecycle::figures::common::run_arm;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;

    let base = ExperimentConfig {
        variant: args.get_or("variant", "cnn_mnist"),
        dataset: args.get_or("dataset", "synth_mnist"),
        workers: args.usize_or("workers", 8),
        rounds: args.usize_or("rounds", 20),
        tau: 2,
        eta: 0.05,
        noniid: true,
        labels_per_worker: 3,
        train_n: 1200,
        test_n: 256,
        eval_every: 4,
        seed: 3,
        ..Default::default()
    };

    println!(
        "{:<22} {:>9} {:>14} {:>14} {:>9}",
        "codec", "accuracy", "floats", "bits", "scalar%"
    );
    for (name, codec) in [
        ("topk(10%)+ef", CodecKind::TopKEf { fraction: 0.1 }),
        ("atomo(rank2)", CodecKind::Atomo { rank: 2 }),
        ("signsgd", CodecKind::SignSgd),
    ] {
        let mut base_floats = 0u64;
        let mut base_bits = 0u64;
        for (suffix, delta) in [("", -1.0), ("+lbgm", 0.2)] {
            let cfg = ExperimentConfig { delta, codec, ..base.clone() };
            let out = run_arm(&rt, &manifest, &cfg, &format!("{name}{suffix}"))?;
            println!(
                "{:<22} {:>8.1}% {:>14} {:>14} {:>8.1}%",
                format!("{name}{suffix}"),
                100.0 * out.series.final_metric(),
                out.ledger.total_floats,
                out.ledger.total_bits,
                100.0 * out.series.scalar_fraction()
            );
            if delta < 0.0 {
                base_floats = out.ledger.total_floats;
                base_bits = out.ledger.total_bits;
            } else {
                println!(
                    "{:<22} saving over {name}: {:.1}% floats, {:.1}% bits",
                    "",
                    100.0 * (1.0 - out.ledger.total_floats as f64 / base_floats as f64),
                    100.0 * (1.0 - out.ledger.total_bits as f64 / base_bits as f64)
                );
            }
        }
    }
    Ok(())
}
