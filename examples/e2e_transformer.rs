//! End-to-end driver (DESIGN.md E11): federated training of the byte-level
//! transformer LM on a synthetic Markov corpus, proving all three layers
//! compose — Pallas matmul kernels inside the JAX-authored grad_step HLO,
//! executed by the Rust coordinator through PJRT, with LBGM managing the
//! uplink.
//!
//!     cargo run --release --example e2e_transformer -- --rounds 200
//!
//! Logs the loss curve and next-token accuracy every few rounds and writes
//! results/e2e_transformer.csv; EXPERIMENTS.md records a reference run.
//!
//! # CI mode: `--mock`
//!
//! `--mock` swaps the PJRT transformer for a [`MockTrainer`] at a bounded
//! transformer-shaped dimension (`--dim`, default 4096) and drives the
//! heterogeneous-fleet scenario harness end to end: the
//! [`FleetSpec::planet_scale`] profile (three device tiers, power-law
//! availability, a participation dip) plus seeded chaos, under the
//! adaptive Theorem-1 policy. No artifacts or PJRT plugin needed, so the
//! chaos-matrix CI can run a realistic model *shape* per `FL_SEED` and
//! publish the per-tier savings ledger:
//!
//!     cargo run --release --example e2e_transformer -- --mock --rounds 12
//!
//! Sanity gates (full round count, finite losses, internally consistent
//! ledger) exit non-zero on violation, so CI catches a silent failure.

use std::path::Path;

use fedrecycle::compress::Identity;
use fedrecycle::config::ExperimentConfig;
use fedrecycle::coordinator::{run_fl, FlConfig, MockTrainer};
use fedrecycle::figures::common::run_arm;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::metrics::{write_csv, write_json, RunSeries};
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::sim::ChaosSpec;
use fedrecycle::testkit::FleetSpec;
use fedrecycle::util::cli::Args;

/// Upper bounds on the CI-facing knobs: `--mock` runs must stay cheap
/// enough for the chaos matrix even when a config typo asks for more.
const MAX_MOCK_ROUNDS: usize = 500;
const MAX_MOCK_DIM: usize = 1 << 16;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    // The chaos-matrix CI parameterizes runs by FL_SEED; an explicit
    // --seed still wins.
    let env_seed = std::env::var("FL_SEED").ok().and_then(|s| s.parse().ok());
    let seed = args.u64_or("seed", env_seed.unwrap_or(4));
    if args.flag("mock") {
        return run_mock_scenario(&args, seed);
    }

    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;
    let meta = manifest.variant("transformer_lm")?;
    println!(
        "transformer_lm: {} parameters, batch {}, seq {} ({})",
        meta.param_count, meta.batch, meta.x_shape[1],
        "vocab-64 Markov corpus"
    );

    let cfg = ExperimentConfig {
        name: "e2e_transformer".into(),
        variant: "transformer_lm".into(),
        dataset: "corpus".into(),
        workers: args.usize_or("workers", 8),
        rounds: args.usize_or("rounds", 200),
        tau: args.usize_or("tau", 2),
        eta: args.f64_or("eta", 0.15),
        delta: args.f64_or("delta", 0.3),
        train_n: 10_000, // validation floor; corpus sharding is by tokens
        eval_every: args.usize_or("eval-every", 10),
        seed,
        ..Default::default()
    };
    println!(
        "federation: K={} rounds={} tau={} eta={} delta={}",
        cfg.workers, cfg.rounds, cfg.tau, cfg.eta, cfg.delta
    );

    let out = run_arm(&rt, &manifest, &cfg, "e2e_transformer")?;

    println!("\nloss curve (train / eval every {} rounds):", cfg.eval_every);
    for r in out
        .series
        .rounds
        .iter()
        .filter(|r| r.round % cfg.eval_every == 0 || r.round + 1 == cfg.rounds)
    {
        println!(
            "  round {:>4}: train loss {:.4} | test loss {:.4} | next-token acc {:.3}",
            r.round, r.train_loss, r.test_loss, r.test_metric
        );
    }
    check_run(&out.series, cfg.rounds)?;
    let first = out.series.rounds.first().expect("non-empty series");
    let last = out.series.last().expect("non-empty series");
    println!(
        "\ntrain loss {:.4} -> {:.4} (uniform baseline ln(64) = {:.4})",
        first.train_loss,
        last.train_loss,
        (64f64).ln()
    );
    println!(
        "uplink: {} floats total, {:.1}% scalar rounds, LBG refreshes amortized",
        out.ledger.total_floats,
        100.0 * out.series.scalar_fraction()
    );
    println!("phase timings: {}", out.timers.report());
    write_csv(Path::new("results/e2e_transformer.csv").as_ref(), &[out.series])?;
    println!("curve written to results/e2e_transformer.csv");
    Ok(())
}

/// The CI-runnable path: the planet-scale scenario over a bounded
/// transformer-shaped mock federation, adaptive policy, seeded chaos,
/// per-tier savings ledger written as JSON.
fn run_mock_scenario(args: &Args, seed: u64) -> anyhow::Result<()> {
    let rounds = args.usize_or("rounds", 12).min(MAX_MOCK_ROUNDS);
    let dim = args.usize_or("dim", 4096).min(MAX_MOCK_DIM);
    let workers = args.usize_or("workers", 10);
    let delta2 = args.f64_or("delta2", 0.05);

    let mut spec = FleetSpec::planet_scale(rounds);
    spec.chaos = Some(ChaosSpec::default());
    let scenario = spec.compile(seed, workers, rounds)?;
    let mut cfg = FlConfig {
        rounds,
        eta: 0.1,
        policy: ThresholdPolicy::AdaptiveDelta2 { delta2, tau: 2 },
        eval_every: args.usize_or("eval-every", 4),
        seed,
        ..Default::default()
    };
    scenario.apply(&mut cfg)?;
    println!(
        "mock transformer-shaped scenario: dim={dim} K={workers} rounds={rounds} \
         seed={seed} tiers={:?}",
        scenario.tiers.names
    );

    let mut trainer = MockTrainer::new(dim, workers, 0.2, 0.02, seed);
    let out = run_fl(
        &mut trainer,
        vec![0.0; dim],
        &cfg,
        &|| Box::new(Identity),
        "e2e_transformer_mock",
    )?;
    check_run(&out.series, rounds)?;
    anyhow::ensure!(out.ledger.consistent(), "communication ledger inconsistent");
    let tiers = out.ledger.tier_totals();
    anyhow::ensure!(
        tiers.len() == scenario.tiers.tier_count(),
        "expected {} tier rows, ledger produced {}",
        scenario.tiers.tier_count(),
        tiers.len()
    );
    for t in &tiers {
        println!(
            "  tier {:>8}: {} workers, {} floats up, {} faults, {} rejoins",
            t.name, t.workers, t.floats_up, t.faults, t.rejoins
        );
    }
    let out_path = args.get_or("out", "results/e2e_transformer_mock.json");
    write_json(Path::new(&out_path), &[out.series])?;
    println!("per-tier ledger written to {out_path}");
    Ok(())
}

/// Shared sanity gates; an `Err` here exits the process non-zero, which
/// is what makes the example usable as a CI smoke step.
fn check_run(series: &RunSeries, rounds: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        series.rounds.len() == rounds,
        "run stopped early: {} of {rounds} rounds",
        series.rounds.len()
    );
    for r in &series.rounds {
        anyhow::ensure!(
            r.train_loss.is_finite() && r.test_loss.is_finite(),
            "non-finite loss at round {}",
            r.round
        );
    }
    Ok(())
}
