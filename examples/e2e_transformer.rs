//! End-to-end driver (DESIGN.md E11): federated training of the byte-level
//! transformer LM on a synthetic Markov corpus, proving all three layers
//! compose — Pallas matmul kernels inside the JAX-authored grad_step HLO,
//! executed by the Rust coordinator through PJRT, with LBGM managing the
//! uplink.
//!
//!     cargo run --release --example e2e_transformer -- --rounds 200
//!
//! Logs the loss curve and next-token accuracy every few rounds and writes
//! results/e2e_transformer.csv; EXPERIMENTS.md records a reference run.

use std::path::Path;

use fedrecycle::config::ExperimentConfig;
use fedrecycle::figures::common::run_arm;
use fedrecycle::metrics::write_csv;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;
    let meta = manifest.variant("transformer_lm")?;
    println!(
        "transformer_lm: {} parameters, batch {}, seq {} ({})",
        meta.param_count, meta.batch, meta.x_shape[1],
        "vocab-64 Markov corpus"
    );

    let cfg = ExperimentConfig {
        name: "e2e_transformer".into(),
        variant: "transformer_lm".into(),
        dataset: "corpus".into(),
        workers: args.usize_or("workers", 8),
        rounds: args.usize_or("rounds", 200),
        tau: args.usize_or("tau", 2),
        eta: args.f64_or("eta", 0.15),
        delta: args.f64_or("delta", 0.3),
        train_n: 10_000, // validation floor; corpus sharding is by tokens
        eval_every: args.usize_or("eval-every", 10),
        seed: args.u64_or("seed", 4),
        ..Default::default()
    };
    println!(
        "federation: K={} rounds={} tau={} eta={} delta={}",
        cfg.workers, cfg.rounds, cfg.tau, cfg.eta, cfg.delta
    );

    let out = run_arm(&rt, &manifest, &cfg, "e2e_transformer")?;

    println!("\nloss curve (train / eval every {} rounds):", cfg.eval_every);
    for r in out
        .series
        .rounds
        .iter()
        .filter(|r| r.round % cfg.eval_every == 0 || r.round + 1 == cfg.rounds)
    {
        println!(
            "  round {:>4}: train loss {:.4} | test loss {:.4} | next-token acc {:.3}",
            r.round, r.train_loss, r.test_loss, r.test_metric
        );
    }
    let first = out.series.rounds.first().unwrap();
    let last = out.series.last().unwrap();
    println!(
        "\ntrain loss {:.4} -> {:.4} (uniform baseline ln(64) = {:.4})",
        first.train_loss,
        last.train_loss,
        (64f64).ln()
    );
    println!(
        "uplink: {} floats total, {:.1}% scalar rounds, LBG refreshes amortized",
        out.ledger.total_floats,
        100.0 * out.series.scalar_fraction()
    );
    println!("phase timings: {}", out.timers.report());
    write_csv(Path::new("results/e2e_transformer.csv").as_ref(), &[out.series])?;
    println!("curve written to results/e2e_transformer.csv");
    Ok(())
}
