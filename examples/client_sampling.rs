//! Client sampling demo (paper Alg. 3, App. F.5): LBGM at partial
//! participation, plus the threaded channel-transport deployment running
//! the same protocol with the analytic mock federation.
//!
//!     cargo run --release --example client_sampling -- --fraction 0.5

use fedrecycle::compress::Identity;
use fedrecycle::config::ExperimentConfig;
use fedrecycle::coordinator::round::{FlConfig, Parallelism};
use fedrecycle::coordinator::trainer::{LocalTrainer, MockTrainer};
use fedrecycle::coordinator::transport::run_threaded_fl;
use fedrecycle::figures::common::run_arm;
use fedrecycle::lbgm::ThresholdPolicy;
use fedrecycle::runtime::{Manifest, Runtime};
use fedrecycle::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let fraction = args.f64_or("fraction", 0.5);

    // --- PJRT path: real CNN federation at partial participation --------
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = Runtime::cpu()?;
    let base = ExperimentConfig {
        variant: "cnn_mnist".into(),
        dataset: "synth_mnist".into(),
        workers: args.usize_or("workers", 10),
        rounds: args.usize_or("rounds", 20),
        tau: 2,
        eta: 0.05,
        noniid: true,
        labels_per_worker: 3,
        sample_fraction: fraction,
        train_n: 1200,
        test_n: 256,
        eval_every: 4,
        seed: 8,
        ..Default::default()
    };
    println!("PJRT federation at {:.0}% participation:", fraction * 100.0);
    let vanilla = run_arm(&rt, &manifest, &ExperimentConfig { delta: -1.0, ..base.clone() }, "vanilla")?;
    let lbgm = run_arm(&rt, &manifest, &ExperimentConfig { delta: 0.2, ..base }, "lbgm")?;
    println!(
        "  vanilla: acc {:.3}, {} floats | lbgm: acc {:.3}, {} floats ({:.1}% saving)",
        vanilla.series.final_metric(),
        vanilla.ledger.total_floats,
        lbgm.series.final_metric(),
        lbgm.ledger.total_floats,
        100.0 * lbgm.series.savings_vs(vanilla.ledger.total_floats)
    );

    // --- Threaded transport path (one OS thread per worker) -------------
    println!("\nthreaded channel transport (mock federation, same protocol):");
    let dim = 64;
    let k = 8;
    let mut eval = MockTrainer::new(dim, k, 0.3, 0.0, 21);
    let weights = eval.weights();
    let cfg = FlConfig {
        rounds: 40,
        tau: 2,
        eta: 0.05,
        policy: ThresholdPolicy::fixed(0.3),
        sample_fraction: fraction,
        eval_every: 10,
        seed: 21,
        check_coherence: false,
        // The channel transport below owns its threading (one long-lived
        // thread per worker); the engine knob is not consulted there.
        parallelism: Parallelism::Sequential,
        ..Default::default()
    };
    let (series, ledger, _) = run_threaded_fl(
        |_| MockTrainer::new(dim, k, 0.3, 0.02, 21),
        &mut eval,
        vec![0.0; dim],
        weights,
        &cfg,
        &|| Box::new(Identity),
        "threaded",
    )?;
    println!(
        "  {} rounds over {} worker threads: loss {:.4} -> {:.4}, {:.1}% scalar uplinks",
        series.rounds.len(),
        k,
        series.rounds[0].train_loss,
        series.last().unwrap().train_loss,
        100.0 * series.scalar_fraction()
    );
    println!("  ledger: {} floats, consistent={}", ledger.total_floats, ledger.consistent());
    Ok(())
}
